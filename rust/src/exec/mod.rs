//! The cluster executor: a discrete-event simulation binding the dynamic
//! workflow engine, a scheduling strategy, the DPS/LCS, a DFS backend,
//! and the flow-level bandwidth substrate.
//!
//! Task lifecycle (mirrors the Nextflow wrapper, §IV-B):
//!
//! ```text
//! ready ──start──▶ stage-in ──▶ compute ──▶ stage-out ──▶ done
//!                  (flows)      (timer)     (flows)
//! ```
//!
//! Baselines stage in/out through the DFS; WOW reads intermediate inputs
//! from the local disk (the node is *prepared*) and writes outputs
//! locally, with COPs moving data between nodes in parallel to execution.
//! A scheduling iteration runs whenever a task finishes, a COP finishes,
//! or new tasks are submitted (§III-B).
//!
//! The executor drives a [`WorkloadSpec`]: N tenant workflows, each with
//! its own [`WorkflowEngine`], sharing one cluster / network / DFS / DPS.
//! Engine-local task and file ids are namespaced per tenant (see
//! [`crate::workload`]); each tenant is submitted at its arrival time and
//! every scheduling iteration runs over the union of ready tasks, ordered
//! across tenants by the configured [`TenantPolicy`]. A single-tenant
//! workload (what [`run`] builds) takes exactly the pre-workload code
//! path: tenant 0's namespace is the identity and an empty precedence
//! vector leaves every strategy on its single-workflow behaviour.

use crate::cluster::{Cluster, NodeId, NodeSpec, Topology};
use crate::dfs::{Ceph, Dfs, DfsKind, Nfs};
use crate::dps::cost::{CostEval, NativeCost, ParallelCost};
use crate::dps::{CopId, CopPlan, Dps};
use crate::fault::{FaultConfig, FaultEvent, FaultPlan, ResilienceConfig};
use crate::lcs::Lcs;
use crate::metrics::{RunMetrics, TenantMetrics};
use crate::net::{FlowId, FlowNet};
use crate::scheduler::wow::WowParams;
use crate::scheduler::{Action, ReadyTask, SchedView, Scheduler, Strategy, TenantPolicy};
use crate::serve::{self, AdmissionPolicy, DequeueOrder, ServeConfig};
use crate::sim::event::EventQueue;
use crate::trace::{SimProfile, Trace, TraceConfig, TraceEvent, Tracer};
use crate::uncertain::{RuntimeOracle, UncEvent, UncPlan, UncertaintyConfig};
use crate::util::fxmap::{FastMap, FastSet};
use crate::util::rng::Rng;
use crate::util::units::{Bandwidth, Bytes, SimTime};
use crate::workflow::engine::WorkflowEngine;
use crate::workflow::spec::WorkflowSpec;
use crate::workflow::task::{FileId, TaskId};
use crate::workload::{self, WorkloadSpec};

/// Which simulation-core implementation drives the run. All three
/// produce bit-identical `RunMetrics`; they differ only in cost. (With
/// a non-native cost backend — the tiled XLA artifact — the executor
/// keeps the full cost-matrix rebuild under every core, because the
/// row cache's bit-identity argument only holds for the native
/// backend's accumulation order.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimCore {
    /// The incremental core: component-restricted max-min recompute,
    /// dirty-tracked cost-matrix rows, O(1) executor bookkeeping.
    #[default]
    Incremental,
    /// Incremental, with naive shadow oracles attached: every FlowNet
    /// observable and every cost matrix is asserted bit-identical
    /// against the pre-refactor algorithms. Slow; for tests.
    Checked,
    /// Incremental recompute and row caches, but with the network's
    /// lazy advance switched off: every advance integrates every live
    /// flow and `next_completion` scans them all. This is the
    /// pre-lazy-advance cost model (the state the O(touched)-per-event
    /// refactor started from), kept as the `bench_scale` before/after
    /// baseline for that change. Results are identical to all cores.
    Eager,
    /// The pre-refactor cost model: full progressive filling on every
    /// network change (which implies eager advance) and a full
    /// cost-matrix rebuild per scheduling iteration. Kept as
    /// `bench_scale`'s oldest baseline. The dominant terms match the
    /// old core exactly; second-order costs differ in both directions
    /// (this mode still pays the incremental index upkeep the old core
    /// lacked, but also enjoys its O(1) lookups where the old core
    /// scanned), so treat measured speedups as estimates of the
    /// algorithmic win, not a cycle-exact A/B.
    Naive,
}

impl std::str::FromStr for SimCore {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "incremental" | "incr" => Ok(SimCore::Incremental),
            "checked" => Ok(SimCore::Checked),
            "eager" => Ok(SimCore::Eager),
            "naive" | "full" => Ok(SimCore::Naive),
            other => {
                anyhow::bail!(
                    "unknown sim core '{other}' (expected incremental|checked|eager|naive)"
                )
            }
        }
    }
}

/// Configuration of one simulated run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub n_nodes: usize,
    pub link_gbit: f64,
    /// Network shape: the paper's flat star (default — bit-identical to
    /// the pre-topology simulator) or a hierarchical rack/zone fabric
    /// with oversubscribed boundary links. Threads through the cluster
    /// (path resolution), the net (flows traverse the real link chain),
    /// the DPS (min-capacity path pricing), the schedulers (via the
    /// cost matrix) and the fault planner (rack/zone crash domains).
    pub topology: Topology,
    pub dfs: DfsKind,
    pub strategy: Strategy,
    pub seed: u64,
    /// WOW COP limits (§V-C defaults: 1 and 2).
    pub c_node: u32,
    pub c_task: u32,
    /// Per-COP setup latency in seconds (scheduler RPC + FTP session to
    /// the LCS daemon). The paper reuses long-lived LCS daemons exactly
    /// because per-copy service startup "could otherwise double"
    /// short-task runtimes (§IV-D); a sub-second session cost remains.
    pub cop_setup_s: f64,
    /// Replica garbage collection (§III-A): delete all replicas of an
    /// intermediate file once no current or future task can read it.
    /// The paper's evaluation kept every replica ("we did not delete any
    /// replicas during our experiments"), so this defaults to off; the
    /// peak-temporary-storage metric quantifies the §VIII trade-off.
    pub replica_gc: bool,
    /// Per-worker relative compute speeds (empty = homogeneous at 1.0).
    /// Lifts the paper's §VIII homogeneity limitation: task compute time
    /// on node i is divided by `speed_factors[i]`.
    pub speed_factors: Vec<f64>,
    /// Fault injection (crashes, brownouts, task failures). The default
    /// injects nothing, and a disabled config takes exactly the
    /// fault-free code path (no extra events, no extra RNG draws).
    pub fault: FaultConfig,
    /// Inter-tenant ordering on multi-tenant workloads. Irrelevant on
    /// single-tenant runs (the executor passes an empty precedence
    /// vector, so both policies take the identical code path).
    pub tenant_policy: TenantPolicy,
    /// Open-serving regime (admission control, preemption, SLO horizon,
    /// cross-tenant dedup). The default is inert — closed-batch runs
    /// take exactly the pre-serve code path, with no extra events and
    /// no extra RNG draws (the serve analogue of `fault`).
    pub serve: ServeConfig,
    /// Proactive resilience (failure-domain-aware replica hedging,
    /// checkpoint/restart, availability-aware placement). The default
    /// disables all three and takes exactly the pre-resilience code
    /// path: no extra events, flows, or RNG draws.
    pub resil: ResilienceConfig,
    /// Runtime uncertainty: truth-vs-estimate runtime noise, node speed
    /// classes and mid-run degradation, the online re-estimator, and
    /// speculative straggler backups. The default is inert — no extra
    /// events, no extra RNG draws, and bit-identical fingerprints to
    /// the pre-uncertainty simulator on every core and thread count.
    pub uncertain: UncertaintyConfig,
    /// Simulation-core selection (incremental / checked / naive); the
    /// choice never changes results, only how fast they are produced.
    pub core: SimCore,
    /// Worker threads for the deterministic parallel core (component
    /// fan-out, replay folds, cost-row batches). `0` consults the
    /// `WOW_THREADS` env var (default 1); `1` is fully sequential. Like
    /// `core`, the choice never changes results — every fan-out folds
    /// back in a pinned order (DESIGN.md §15), so any thread count
    /// yields bit-identical metrics.
    pub threads: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            n_nodes: 8,
            link_gbit: 1.0,
            topology: Topology::Flat,
            dfs: DfsKind::Ceph,
            strategy: Strategy::Wow,
            seed: 0,
            c_node: 1,
            c_task: 2,
            cop_setup_s: 0.5,
            replica_gc: false,
            speed_factors: Vec::new(),
            fault: FaultConfig::default(),
            tenant_policy: TenantPolicy::Fifo,
            serve: ServeConfig::default(),
            resil: ResilienceConfig::default(),
            uncertain: UncertaintyConfig::default(),
            core: SimCore::Incremental,
            threads: 0,
        }
    }
}

/// Run `spec` under `cfg` with the default (native) cost backend.
pub fn run(spec: &WorkflowSpec, cfg: &RunConfig) -> RunMetrics {
    run_with_backend(spec, cfg, Box::new(NativeCost))
}

/// Run with an explicit DPS cost backend (e.g. the XLA artifact).
pub fn run_with_backend(
    spec: &WorkflowSpec,
    cfg: &RunConfig,
    backend: Box<dyn CostEval>,
) -> RunMetrics {
    run_workload_with_backend(&WorkloadSpec::solo(spec.clone()), cfg, backend)
}

/// Run a multi-tenant workload with the default (native) cost backend.
pub fn run_workload(workload: &WorkloadSpec, cfg: &RunConfig) -> RunMetrics {
    run_workload_with_backend(workload, cfg, Box::new(NativeCost))
}

/// Run a multi-tenant workload with an explicit DPS cost backend.
pub fn run_workload_with_backend(
    workload: &WorkloadSpec,
    cfg: &RunConfig,
    backend: Box<dyn CostEval>,
) -> RunMetrics {
    Executor::new(workload.clone(), cfg.clone(), backend).run_observed(false).metrics
}

/// What to observe during a run, on top of the metrics every run
/// produces. The default observes nothing and is byte-identical to the
/// plain entry points.
#[derive(Debug, Clone, Default)]
pub struct ObserveConfig {
    /// Record a structured event trace ([`crate::trace`]).
    pub trace: Option<TraceConfig>,
    /// Collect simulator self-metrics (event/recompute counters plus
    /// wall-clock section timers).
    pub profile: bool,
}

/// A run's metrics plus whatever observation artifacts were requested.
pub struct RunOutput {
    pub metrics: RunMetrics,
    pub trace: Option<Trace>,
    pub profile: Option<SimProfile>,
}

/// Run a multi-tenant workload, optionally recording a trace and/or a
/// simulator profile. Observation is strictly passive: `metrics` (and
/// its fingerprint) are bit-identical whatever `obs` requests.
pub fn run_workload_observed(
    workload: &WorkloadSpec,
    cfg: &RunConfig,
    backend: Box<dyn CostEval>,
    obs: &ObserveConfig,
) -> RunOutput {
    let mut ex = Executor::new(workload.clone(), cfg.clone(), backend);
    if let Some(tc) = &obs.trace {
        ex.tracer = Tracer::new(tc);
    }
    ex.prof_wall = obs.profile;
    ex.run_observed(obs.profile)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    StageIn,
    Compute,
    StageOut,
}

#[derive(Debug)]
struct Running {
    node: NodeId,
    phase: Phase,
    pending_flows: usize,
    started: SimTime,
    /// When the current compute attempt began (wasted-work accounting
    /// for injected task failures).
    compute_started: SimTime,
    /// Execution attempt id: a `ComputeDone` from an execution that a
    /// crash killed must not touch the task's next incarnation.
    attempt: u64,
    cores: u32,
    mem: Bytes,
    /// Base-equivalent compute seconds per wall second of this attempt
    /// (speed / inflation). Only maintained when checkpointing is on.
    rate: f64,
    /// Committed (checkpointed) base seconds when this attempt began —
    /// the point the attempt resumed from.
    base_offset: f64,
    /// Wall seconds of this attempt's compute covered by the last
    /// *committed* checkpoint; the salvage in `kill_running`. Always 0
    /// with checkpointing off, keeping the wasted-work split inert.
    ckpt_wall: f64,
    /// Lognormal truth factor of this attempt's compute draw (runtime
    /// uncertainty); exactly 1.0 when the subsystem is off. Fed back
    /// to the re-estimator when the attempt's compute succeeds.
    unc_tfac: f64,
}

/// Sentinel task id owning hedge COPs: never collides with namespaced
/// task ids (tenant counts stay far below 2^24) and never appears in
/// the ready queue, so hedge COPs share the DPS COP machinery without
/// touching any per-task scheduling state.
const HEDGE_TASK: TaskId = TaskId(u64::MAX);

/// The DFS object a task's checkpoints are written to. High bit set:
/// disjoint from every namespaced workflow file, and stable per task so
/// Ceph places it once and overwrites thereafter.
fn ckpt_file(task: TaskId) -> FileId {
    FileId((1u64 << 63) | task.0)
}

/// A checkpoint write in flight: committed only when all of its DFS
/// flows finish while the same attempt is still computing.
#[derive(Debug)]
struct CkptPending {
    attempt: u64,
    flows: usize,
    /// Total committed base seconds if this checkpoint lands.
    base_done: f64,
    /// Wall seconds into the attempt's compute at the cut.
    cut_wall: f64,
    bytes: Bytes,
}

#[derive(Debug)]
enum Event {
    /// Compute finished for the given execution attempt (stale attempts
    /// are ignored — the task was killed and restarted meanwhile).
    ComputeDone(TaskId, u64),
    /// COP setup latency elapsed: launch its flows.
    CopLaunch(CopId),
    /// Injected fault from the compiled `FaultPlan`.
    Fault(FaultEvent),
    /// A tenant's workflow arrives: its inputs register in the DFS and
    /// its source tasks materialize.
    TenantArrive(usize),
    /// Periodic checkpoint tick for a computing attempt (stale attempts
    /// are ignored, like `ComputeDone`). Only ever scheduled when
    /// `ResilienceConfig::checkpoint_every_s > 0`.
    Checkpoint(TaskId, u64),
    /// Straggler probe for a computing attempt (stale attempts are
    /// ignored, like `ComputeDone`). Only ever scheduled when
    /// speculation is on.
    StragglerCheck(TaskId, u64),
    /// A worker enters a compiled performance-degradation window
    /// (runtime-uncertainty plan, not fault injection).
    UncDegrade(usize),
    /// One degradation window on the worker ends.
    UncRestore(usize),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum FlowOwner {
    StageIn(TaskId),
    StageOut(TaskId),
    /// DFS re-replication after a crash (fire-and-forget; traffic only).
    Recovery,
    /// A checkpoint write of a computing task (checkpointing only; does
    /// not gate any phase barrier).
    Checkpoint(TaskId),
}

/// Runtime state of one tenant: its dynamic engine plus per-tenant
/// accounting. Engine-local ids are namespaced via [`crate::workload`]
/// before they touch any shared structure.
struct TenantRt {
    name: String,
    engine: WorkflowEngine,
    arrival: SimTime,
    weight: f64,
    arrived: bool,
    first_start: Option<SimTime>,
    last_finish: SimTime,
    /// Cores currently allocated to this tenant's running tasks — the
    /// fair-share policy's usage signal.
    running_cores: u64,
    /// Shed by the admission controller: never submitted anything.
    rejected: bool,
    /// All tasks done — the tenant's admission slot has been released.
    /// Lineage healing can flip this back (revived work re-occupies it).
    finished: bool,
    /// Static expected core-seconds of the workflow — the admission
    /// controller's price (computed from the spec, zero RNG draws).
    work_est_s: f64,
    /// Workflow-spec name, kept for cross-tenant content keys (the
    /// engine consumes the spec).
    workflow_name: String,
    /// Per-stage static core-second estimates — the oracle's admission
    /// repricing basis. Empty unless runtime uncertainty is on.
    stage_est: Vec<f64>,
}

/// A finished COP awaiting usefulness attribution, indexed by its
/// destination node: the record is dropped — and `n_cops_used` bumped —
/// when a task starting on that node reads any of `files` (Table II's
/// "used" column). Streaming fold: attributed COPs leave no resident
/// record, so memory tracks the unused backlog, not every COP ever
/// completed.
#[derive(Debug)]
struct CompletedCop {
    id: CopId,
    files: Vec<FileId>,
}

struct Executor {
    cfg: RunConfig,
    workload_name: String,
    tenants: Vec<TenantRt>,
    scheduler: Box<dyn Scheduler>,
    net: FlowNet,
    cluster: Cluster,
    dfs: Box<dyn Dfs>,
    dps: Dps,
    lcs: Lcs,
    events: EventQueue<Event>,
    rng: Rng,

    /// Ready queue in submission order. Started tasks are tombstoned
    /// (`ready_dead`) in O(1) and compacted away at the next scheduling
    /// iteration, so the slice handed to schedulers stays dense while
    /// `start_task`/`start_cop` never scan.
    ready: Vec<ReadyTask>,
    ready_dead: Vec<bool>,
    n_ready_dead: usize,
    /// id → position in `ready` (live entries only).
    ready_pos: FastMap<TaskId, usize>,
    running: FastMap<TaskId, Running>,
    flow_owner: FastMap<FlowId, FlowOwner>,
    /// Reverse index of `flow_owner` for stage-in/out flows: task → its
    /// live flows in ascending id order (crash handling's
    /// `flows_of_task` used to scan every flow).
    task_flows: FastMap<TaskId, Vec<FlowId>>,
    submitted_seq: u64,

    // Metrics accumulation.
    first_start: Option<SimTime>,
    last_finish: SimTime,
    cpu_core_seconds: f64,
    node_cpu_seconds: Vec<f64>,
    /// Tasks that ever had a COP created for them (`tasks_no_cop` is
    /// its complement). A set, not a count map — the metric only asks
    /// "any COP?", so per-task counters would grow resident memory with
    /// the task count for no observable.
    tasks_with_cops: FastSet<TaskId>,
    /// Not-yet-used completed COPs indexed by destination node, so the
    /// usefulness attribution in `start_task` touches only that node's
    /// candidates instead of every COP ever completed. Attributed COPs
    /// are dropped on the spot (see [`CompletedCop`]).
    unused_cops_by_node: FastMap<NodeId, Vec<CompletedCop>>,
    /// COPs whose data a task read on the destination (Table II "used").
    n_cops_used: u64,
    /// COPs in their setup-latency window, not yet flowing.
    pending_cops: FastMap<CopId, crate::dps::Cop>,
    tasks_done: usize,
    /// Current / peak bytes of WOW-managed intermediate replicas per
    /// worker (temporary-storage accounting; peak is what §VIII's
    /// fault-tolerance trade-off is about).
    node_replica_bytes: Vec<f64>,
    peak_replica_bytes: f64,

    // Fault injection & recovery state (inert on fault-free runs).
    /// Independent RNG stream for failure sampling so injection never
    /// perturbs workload or placement randomness.
    fault_rng: Rng,
    /// Monotone execution-attempt counter (see `Running::attempt`).
    exec_seq: u64,
    /// Injected failures per task so far (the retry bound input).
    retries: FastMap<TaskId, u32>,
    /// Active brownouts per node: capacity is restored only when the
    /// last overlapping brownout ends.
    degraded: FastMap<NodeId, u32>,
    wasted_core_seconds: f64,
    recovery_bytes: Bytes,
    n_crashes: u64,
    n_degrades: u64,
    task_failures: u64,
    tasks_rerun: u64,
    /// Active brownouts per rack uplink (rack-link fault injection).
    degraded_racks: FastMap<usize, u32>,

    // Proactive-resilience state (inert when `cfg.resil` is default:
    // every map stays empty and every counter zero).
    /// Hedge COPs in flight per file (destination nodes), so coverage
    /// checks count hedges already launched but not yet landed.
    hedged: FastMap<FileId, Vec<NodeId>>,
    /// COP id → hedged file, marking which COPs are hedges.
    hedge_cop_ids: FastMap<CopId, FileId>,
    hedge_bytes: Bytes,
    n_hedge_cops: u64,
    /// Durably checkpointed base-equivalent compute seconds per task
    /// (survives kills; the restart point of the next attempt).
    ckpt_committed: FastMap<TaskId, f64>,
    /// Checkpoint writes whose DFS flows are still draining.
    ckpt_pending: FastMap<TaskId, CkptPending>,
    n_checkpoints: u64,
    checkpoint_bytes: Bytes,
    salvaged_core_seconds: f64,

    // Serving-regime state (inert when `cfg.serve` is default).
    /// Tenants waiting for an admission slot, in arrival order.
    admit_queue: Vec<usize>,
    /// Admitted-but-unfinished tenants (the queue policy's slot count).
    active_tenants: usize,
    /// Estimated core-seconds of admitted-but-unfinished tenants (the
    /// load-shedding policy's signal).
    outstanding_work_s: f64,
    n_rejected: u64,
    n_queued: u64,
    n_preempted: u64,
    preempted_core_seconds: f64,
    /// Preemptions per task: each task may be evicted at most once per
    /// run, bounding total preemptions and guaranteeing progress.
    preempt_counts: FastMap<TaskId, u32>,
    /// DFS reads avoided by cross-tenant reference-replica sharing.
    dedup_bytes: Bytes,

    // Runtime-uncertainty state (inert when `cfg.uncertain` is default:
    // the plan is empty, the oracle is `None`, no events are queued and
    // every counter stays zero).
    /// Static per-worker speed classes from the compiled plan (empty on
    /// disabled runs — every node implicitly class 1.0).
    unc_class: Vec<f64>,
    /// Active degradation windows per worker.
    unc_degraded: Vec<u32>,
    /// The online runtime re-estimator; `Some` exactly when the
    /// uncertainty subsystem is enabled.
    oracle: Option<RuntimeOracle>,
    /// Canonical ids of tasks with an unresolved speculative backup.
    spec_pending: FastSet<TaskId>,
    n_spec_launches: u64,
    n_spec_wins: u64,
    /// Core-seconds burned by losing speculative copies.
    spec_wasted_core_seconds: f64,
    /// Degradation windows opened (the `node_degrades` metric).
    n_unc_degrades: u64,

    // Observability (inert by default: the tracer is a `None` branch and
    // the profile counters are plain increments; neither touches RNG or
    // any state that feeds `RunMetrics`).
    tracer: Tracer,
    prof: SimProfile,
    /// Gate for the `Instant`-based wall timers (counter increments are
    /// always on; reading the clock is opt-in via `--profile`).
    prof_wall: bool,
}

impl Executor {
    fn new(workload: WorkloadSpec, cfg: RunConfig, backend: Box<dyn CostEval>) -> Self {
        assert!(!workload.tenants.is_empty(), "workload needs at least one tenant");
        let threads = crate::sim::pool::resolve_threads(cfg.threads);
        let mut net = FlowNet::new();
        net.set_threads(threads);
        match cfg.core {
            SimCore::Incremental => {}
            SimCore::Checked => net.enable_reference_check(),
            SimCore::Eager => net.set_eager_advance(true),
            SimCore::Naive => net.set_full_recompute(true),
        }
        let needs_server = cfg.dfs == DfsKind::Nfs;
        let mut cluster = Cluster::build_topo(
            &mut net,
            cfg.n_nodes,
            NodeSpec::paper_worker(cfg.link_gbit),
            needs_server.then(|| NodeSpec::paper_nfs_server(cfg.link_gbit)),
            cfg.topology,
        );
        // Heterogeneous compute speeds (§VIII extension).
        for (i, &f) in cfg.speed_factors.iter().enumerate().take(cfg.n_nodes) {
            assert!(f > 0.0, "speed factor must be positive");
            cluster.node_mut(crate::cluster::NodeId(i)).spec.speed = f;
        }
        let dfs: Box<dyn Dfs> = match cfg.dfs {
            // Resilience opts Ceph into CRUSH-style rack-aware replica
            // spreading; the default placement stream is untouched.
            DfsKind::Ceph => Box::new(Ceph::new().with_rack_awareness(cfg.resil.enabled())),
            DfsKind::Nfs => Box::new(Nfs::new(cluster.nfs_server().expect("server"))),
        };
        // Deterministic parallel cost rows: wrap the native backend so
        // row batches fan out on the pool with bit-identical results
        // (`ParallelCost` also reports "native" — observationally it is
        // the native backend). Non-native backends are left alone; their
        // accumulation contract belongs to the artifact.
        let backend: Box<dyn CostEval> = if threads > 1 && backend.backend_name() == "native" {
            Box::new(ParallelCost::new(threads))
        } else {
            backend
        };
        // The row cache is bit-identical to the full rebuild only for
        // the native backend (tiled backends fold per-tile partial sums,
        // so their float grouping depends on the batch's file universe);
        // keep non-native backends on the full rebuild so `--xla` runs
        // reproduce the pre-refactor numbers exactly.
        let incremental = cfg.core != SimCore::Naive && backend.backend_name() == "native";
        let params = WowParams {
            c_node: cfg.c_node,
            c_task: cfg.c_task,
            backend,
            incremental,
            hazard_weight: cfg.resil.hazard_weight,
        };
        let scheduler = cfg.strategy.build(params);
        let mut dps = Dps::new(cfg.seed);
        dps.set_reference_check(cfg.core == SimCore::Checked);
        // Hierarchical topology: the DPS prices transfers at the
        // min-capacity link on the path. `topo_view()` is `None` on
        // flat clusters, keeping their cost path untouched.
        if let Some(tv) = cluster.topo_view() {
            dps.set_topology(tv);
        }
        let workload_name = workload.name;
        let unc_on = cfg.uncertain.enabled();
        let tenants: Vec<TenantRt> = workload
            .tenants
            .into_iter()
            .enumerate()
            .map(|(i, ts)| {
                // Price the workflow before the engine consumes the spec
                // (pure arithmetic — the estimator draws no randomness).
                let work_est_s = serve::estimate_core_s(&ts.workflow);
                let stage_est =
                    if unc_on { serve::estimate_stage_core_s(&ts.workflow) } else { Vec::new() };
                let workflow_name = ts.workflow.name.clone();
                TenantRt {
                    engine: WorkflowEngine::new(ts.workflow, workload::tenant_seed(cfg.seed, i)),
                    name: ts.name,
                    arrival: ts.arrival,
                    weight: ts.weight,
                    arrived: false,
                    first_start: None,
                    last_finish: SimTime::ZERO,
                    running_cores: 0,
                    rejected: false,
                    finished: false,
                    work_est_s,
                    workflow_name,
                    stage_est,
                }
            })
            .collect();
        let n_workers = cluster.n_workers();
        Executor {
            workload_name,
            tenants,
            scheduler,
            net,
            cluster,
            dfs,
            dps,
            lcs: Lcs::new(),
            events: EventQueue::new(),
            rng: Rng::new(cfg.seed ^ 0xEC5E_C0DE),
            ready: Vec::new(),
            ready_dead: Vec::new(),
            n_ready_dead: 0,
            ready_pos: FastMap::default(),
            running: FastMap::default(),
            flow_owner: FastMap::default(),
            task_flows: FastMap::default(),
            submitted_seq: 0,
            first_start: None,
            last_finish: SimTime::ZERO,
            cpu_core_seconds: 0.0,
            node_cpu_seconds: vec![0.0; n_workers],
            tasks_with_cops: FastSet::default(),
            unused_cops_by_node: FastMap::default(),
            n_cops_used: 0,
            pending_cops: FastMap::default(),
            tasks_done: 0,
            node_replica_bytes: vec![0.0; n_workers],
            peak_replica_bytes: 0.0,
            fault_rng: Rng::new(cfg.seed ^ 0xFA01_7CA5_0BAD_C0DE),
            exec_seq: 0,
            retries: FastMap::default(),
            degraded: FastMap::default(),
            wasted_core_seconds: 0.0,
            recovery_bytes: Bytes::ZERO,
            n_crashes: 0,
            n_degrades: 0,
            task_failures: 0,
            tasks_rerun: 0,
            degraded_racks: FastMap::default(),
            hedged: FastMap::default(),
            hedge_cop_ids: FastMap::default(),
            hedge_bytes: Bytes::ZERO,
            n_hedge_cops: 0,
            ckpt_committed: FastMap::default(),
            ckpt_pending: FastMap::default(),
            n_checkpoints: 0,
            checkpoint_bytes: Bytes::ZERO,
            salvaged_core_seconds: 0.0,
            admit_queue: Vec::new(),
            active_tenants: 0,
            outstanding_work_s: 0.0,
            n_rejected: 0,
            n_queued: 0,
            n_preempted: 0,
            preempted_core_seconds: 0.0,
            preempt_counts: FastMap::default(),
            dedup_bytes: Bytes::ZERO,
            unc_class: Vec::new(),
            unc_degraded: vec![0; n_workers],
            oracle: unc_on.then(|| RuntimeOracle::new(&cfg.uncertain)),
            spec_pending: FastSet::default(),
            n_spec_launches: 0,
            n_spec_wins: 0,
            spec_wasted_core_seconds: 0.0,
            n_unc_degrades: 0,
            tracer: Tracer::off(),
            prof: SimProfile::default(),
            prof_wall: false,
            cfg,
        }
    }

    fn run_observed(mut self, profile: bool) -> RunOutput {
        let wall0 = self.prof_wall.then(std::time::Instant::now);
        // Compile and enqueue the fault schedule. A disabled config
        // yields an empty plan: no events, no RNG draws, zero drift from
        // the fault-free path.
        let plan = FaultPlan::compile_with_topology(
            &self.cfg.fault,
            self.cluster.n_workers(),
            self.cluster.nfs_server(),
            self.cluster.worker_racks(),
            self.cluster.rack_zones(),
            self.cfg.seed,
        );
        // Resilience seeding (enabled-only; both calls are pure — zero
        // RNG draws, so the disabled path is untouched).
        if self.cfg.resil.hedge_k > 0 {
            // Failure domains for hedge diversity: racks on hierarchical
            // topologies, node identity on flat (every node its own
            // domain, so hedging degenerates to plain replication).
            let racks = self.cluster.worker_racks();
            let domains = if racks.is_empty() {
                (0..self.cluster.n_workers()).collect()
            } else {
                racks.to_vec()
            };
            self.dps.set_failure_domains(domains);
        }
        if self.cfg.resil.hazard_weight > 0.0 {
            // Hazard priors from the compiled schedule: c planned
            // crashes → c/(c+1), i.e. 0 for never-crashing nodes.
            // Observed crashes sharpen these online (EWMA toward 1).
            let crashes = plan.planned_crashes(self.cluster.n_workers());
            self.dps
                .set_hazard(crashes.iter().map(|&c| c as f64 / (c as f64 + 1.0)).collect());
        }
        for (t, ev) in plan.events {
            self.events.push(t, Event::Fault(ev));
        }
        // Compile and enqueue the runtime-uncertainty plan (node speed
        // classes + degradation windows). Skipped outright on disabled
        // configs: no plan, no RNG, no events.
        if self.cfg.uncertain.enabled() {
            let unc =
                UncPlan::compile(&self.cfg.uncertain, self.cluster.n_workers(), self.cfg.seed);
            self.unc_class = unc.node_speed;
            for (t, ev) in unc.events {
                let ev = match ev {
                    UncEvent::Degrade(n) => Event::UncDegrade(n),
                    UncEvent::Restore(n) => Event::UncRestore(n),
                };
                self.events.push(t, ev);
            }
        }
        // Tenants arriving at t = 0 submit immediately (register inputs
        // in the DFS — pre-fetched per §V-A — and materialize source
        // tasks); later arrivals go through the event queue.
        for i in 0..self.tenants.len() {
            let at = self.tenants[i].arrival;
            if at == SimTime::ZERO {
                self.on_tenant_arrival(i);
            } else {
                self.events.push(at, Event::TenantArrive(i));
            }
        }
        self.schedule();

        // Main DES loop.
        loop {
            if self.workload_done() {
                break;
            }
            let t_flow = self.net.next_completion().unwrap_or(SimTime::FAR_FUTURE);
            let t_event = self.events.peek_time().unwrap_or(SimTime::FAR_FUTURE);
            let t = t_flow.min(t_event);
            assert!(
                t != SimTime::FAR_FUTURE,
                "deadlock: no pending events; ready={} running={} arrived={}/{} done={}/{}",
                self.ready.len() - self.n_ready_dead,
                self.running.len(),
                self.tenants.iter().filter(|t| t.arrived).count(),
                self.tenants.len(),
                self.tenants.iter().map(|t| t.engine.n_tasks_completed()).sum::<usize>(),
                self.tenants.iter().map(|t| t.engine.n_tasks_materialized()).sum::<usize>()
            );
            // Interval samplers fire at grid points strictly before `t`.
            // All sampled state is piecewise-constant on `[now, t)` (no
            // event fires in between), so we stamp the *current* state at
            // the grid time without advancing the network there —
            // splitting a flow step at a sample instant would change the
            // f64 fold order and perturb the fingerprint.
            while let Some(g) = self.tracer.due_sample(t) {
                let s = self.sample_state();
                self.tracer.record_sample(g, s);
            }
            let w = self.prof_wall.then(std::time::Instant::now);
            self.net.advance_to(t);
            if let Some(w) = w {
                self.prof.wall_net_s += w.elapsed().as_secs_f64();
            }

            let mut need_schedule = false;

            // Flow completions.
            for flow in self.net.take_completed() {
                self.prof.flow_completions += 1;
                if let Some(owner) = self.disown_flow(flow) {
                    need_schedule |= self.flow_finished(owner, t);
                } else if let Some(cop_id) = self.lcs.flow_done(flow) {
                    self.cop_finished(cop_id);
                    need_schedule = true;
                }
            }
            // Timed events.
            while self.events.peek_time() == Some(t) {
                let (_, ev) = self.events.pop().unwrap();
                self.prof.events_processed += 1;
                match ev {
                    Event::ComputeDone(task, attempt) => {
                        // Ignore completions from executions a crash
                        // killed; the task runs again elsewhere.
                        let valid = matches!(
                            self.running.get(&task),
                            Some(r) if r.attempt == attempt && r.phase == Phase::Compute
                        );
                        if !valid {
                            continue;
                        }
                        if self.compute_attempt_fails(task) {
                            self.retry_compute(task, t);
                        } else {
                            // The uncertainty hook lives here — not in
                            // `start_stage_out`, which restarts also
                            // re-enter — so each successful compute is
                            // observed exactly once and a speculative
                            // pair resolves before either copy writes
                            // outputs.
                            self.on_compute_success(task, t);
                            self.start_stage_out(task, t);
                        }
                    }
                    Event::CopLaunch(id) => {
                        // The COP may have been aborted by a crash during
                        // its setup window, or its sources invalidated.
                        if let Some(cop) = self.pending_cops.remove(&id) {
                            let sources_ok = cop
                                .parts
                                .iter()
                                .all(|(f, src, _)| self.dps.locations(*f).contains(src));
                            if sources_ok && self.cluster.node(cop.dst).alive {
                                self.lcs.start_cop(&cop, &self.cluster, &mut self.net);
                            } else {
                                if let Some(aborted) = self.dps.abort_cop(id) {
                                    self.note_cop_aborted(id, aborted.dst);
                                    self.tracer.emit(t, || TraceEvent::CopAbort {
                                        cop: id.0,
                                        reason: "sources-lost",
                                    });
                                }
                                need_schedule = true;
                            }
                        }
                    }
                    Event::Fault(fe) => {
                        need_schedule |= self.apply_fault(fe, t);
                    }
                    Event::TenantArrive(i) => {
                        self.on_tenant_arrival(i);
                        need_schedule = true;
                    }
                    Event::Checkpoint(task, attempt) => {
                        self.on_checkpoint(task, attempt, t);
                    }
                    Event::StragglerCheck(task, attempt) => {
                        need_schedule |= self.on_straggler_check(task, attempt, t);
                    }
                    Event::UncDegrade(n) => self.on_unc_degrade(n, t),
                    Event::UncRestore(n) => self.on_unc_restore(n, t),
                }
            }
            // A scheduling iteration is observably a no-op when nothing
            // is ready: every strategy returns no actions and draws no
            // randomness on an empty queue, so skip the call outright
            // (common during long drain phases). Any broader skip would
            // desync WOW's COP-planning RNG stream.
            if need_schedule && self.ready.len() > self.n_ready_dead {
                self.schedule();
            }
        }

        let metrics = self.finish_metrics();
        let profile = profile.then(|| {
            let mut p = self.prof.clone();
            let (recomputes, folds, steps, mts) = self.net.profile_counters();
            p.net_recomputes = recomputes;
            p.replay_folds = folds;
            p.replay_steps = steps;
            p.mts_ops = mts;
            p.trace_events = self.tracer.len() as u64;
            if let Some(w) = wall0 {
                p.wall_total_s = w.elapsed().as_secs_f64();
            }
            p
        });
        let tracer = std::mem::replace(&mut self.tracer, Tracer::off());
        RunOutput { metrics, trace: tracer.finish(self.cluster.n_workers()), profile }
    }

    /// Snapshot the sampled gauges at the current instant (queue depths,
    /// core occupancy, rack-uplink utilization, live replica bytes).
    /// Read-only: borrows `&self` so it cannot perturb the run.
    fn sample_state(&self) -> TraceEvent {
        let node_util: Vec<f64> = self
            .cluster
            .workers()
            .map(|n| {
                let node = self.cluster.node(n);
                (node.spec.cores - node.free_cores) as f64 / node.spec.cores as f64
            })
            .collect();
        let rack_util: Vec<f64> = (0..self.cluster.n_racks())
            .map(|r| {
                let (up, _, cap) = self.cluster.rack_link(r);
                if cap > 0.0 { self.net.resource_rate(up) / cap } else { 0.0 }
            })
            .collect();
        TraceEvent::Sample {
            running: self.running.len() as u64,
            ready: (self.ready.len() - self.n_ready_dead) as u64,
            admit_queue: self.admit_queue.len() as u64,
            replica_gb: self.node_replica_bytes.iter().sum::<f64>() / 1e9,
            node_util,
            rack_util,
        }
    }

    /// All tenants have arrived and either been shed or finished every
    /// task. Queued tenants count as not-arrived until admitted, so the
    /// loop keeps running while the admission queue drains.
    fn workload_done(&self) -> bool {
        self.tenants.iter().all(|t| t.arrived && (t.rejected || t.engine.all_done()))
    }

    /// A tenant hits the admission controller at its arrival instant.
    /// The default `AdmitAll` submits immediately — byte for byte the
    /// closed-batch path (the counters it bumps are pure bookkeeping).
    fn on_tenant_arrival(&mut self, tenant: usize) {
        // Runtime uncertainty on: admission prices the tenant from the
        // oracle's current per-stage estimates, never the truth. Early
        // arrivals see the static bias; later ones benefit from EWMA
        // corrections learned so far.
        if let Some(o) = self.oracle.as_ref() {
            let t = &self.tenants[tenant];
            let est: f64 = t
                .stage_est
                .iter()
                .enumerate()
                .map(|(si, &s)| {
                    s * o.estimate_factor(crate::uncertain::type_key(&t.workflow_name, si as u32))
                })
                .sum();
            self.tenants[tenant].work_est_s = est;
        }
        match self.cfg.serve.admission {
            AdmissionPolicy::AdmitAll => self.admit_tenant(tenant),
            AdmissionPolicy::Queue { active, depth, .. } => {
                if self.active_tenants < active {
                    self.admit_tenant(tenant);
                } else if self.admit_queue.len() < depth {
                    self.admit_queue.push(tenant);
                    self.n_queued += 1;
                    self.trace_admission(tenant, "queue");
                } else {
                    self.reject_tenant(tenant);
                }
            }
            AdmissionPolicy::LoadShed { max_core_s } => {
                let est = self.tenants[tenant].work_est_s;
                if self.active_tenants == 0 || self.outstanding_work_s + est <= max_core_s {
                    self.admit_tenant(tenant);
                } else {
                    self.reject_tenant(tenant);
                }
            }
        }
    }

    fn admit_tenant(&mut self, tenant: usize) {
        self.active_tenants += 1;
        self.outstanding_work_s += self.tenants[tenant].work_est_s;
        self.trace_admission(tenant, "admit");
        self.arrive_tenant(tenant);
    }

    /// Trace one admission-controller decision (covers initial arrivals
    /// and queue dequeues alike — a queued tenant shows "queue" at
    /// arrival and "admit" when its slot frees up).
    fn trace_admission(&mut self, tenant: usize, decision: &'static str) {
        let now = self.net.now();
        let name = &self.tenants[tenant].name;
        self.tracer.emit(now, || TraceEvent::Admission { tenant: name.clone(), decision });
    }

    /// Shed the tenant: it never registers inputs, never materializes
    /// tasks, and consumes no randomness — only the rejection counters
    /// move.
    fn reject_tenant(&mut self, tenant: usize) {
        let t = &mut self.tenants[tenant];
        debug_assert!(!t.arrived, "tenant rejected twice");
        t.arrived = true;
        t.rejected = true;
        self.n_rejected += 1;
        self.trace_admission(tenant, "reject");
    }

    /// A tenant's last task completed: release its admission slot and
    /// let queued arrivals in.
    fn tenant_finished(&mut self, tenant: usize) {
        let t = &mut self.tenants[tenant];
        debug_assert!(!t.finished, "tenant finished twice");
        t.finished = true;
        self.active_tenants -= 1;
        self.outstanding_work_s = (self.outstanding_work_s - t.work_est_s).max(0.0);
        self.drain_admit_queue();
    }

    /// Lineage healing revived work of an already-finished tenant: it
    /// re-occupies its admission slot until it drains again.
    fn tenant_unfinished(&mut self, tenant: usize) {
        let t = &mut self.tenants[tenant];
        if !t.finished {
            return;
        }
        t.finished = false;
        self.active_tenants += 1;
        self.outstanding_work_s += t.work_est_s;
    }

    /// Admit queued tenants while slots are free. `Fifo` keeps arrival
    /// order; `Shortest` picks the smallest work estimate (ties keep
    /// queue order), the admission-level shortest-job-first.
    fn drain_admit_queue(&mut self) {
        let AdmissionPolicy::Queue { active, order, .. } = self.cfg.serve.admission else {
            return;
        };
        while self.active_tenants < active && !self.admit_queue.is_empty() {
            let pos = match order {
                DequeueOrder::Fifo => 0,
                DequeueOrder::Shortest => {
                    let mut best = 0;
                    for i in 1..self.admit_queue.len() {
                        if self.tenants[self.admit_queue[i]].work_est_s
                            < self.tenants[self.admit_queue[best]].work_est_s
                        {
                            best = i;
                        }
                    }
                    best
                }
            };
            let t = self.admit_queue.remove(pos);
            self.admit_tenant(t);
        }
    }

    /// A tenant's workflow is submitted: its input files register in the
    /// DFS and its source tasks materialize and queue.
    fn arrive_tenant(&mut self, tenant: usize) {
        debug_assert!(!self.tenants[tenant].arrived, "tenant arrived twice");
        self.tenants[tenant].arrived = true;
        let inputs: Vec<(FileId, Bytes)> = self.tenants[tenant]
            .engine
            .input_files()
            .iter()
            .map(|&f| (f, self.tenants[tenant].engine.file(f).size))
            .collect();
        for (f, size) in &inputs {
            self.dfs.register_input(
                workload::ns_file(tenant, *f),
                *size,
                &self.cluster,
                &mut self.rng,
            );
        }
        // Cross-tenant dedup: tag each reference input with its content
        // key so stage-ins can share replicas other tenants already
        // pulled onto a node.
        if self.cfg.serve.dedup {
            for (idx, (f, size)) in inputs.iter().enumerate() {
                self.dps.register_reference(
                    workload::ns_file(tenant, *f),
                    serve::content_key(&self.tenants[tenant].workflow_name, idx as u64, *size),
                );
            }
        }
        let initial = self.tenants[tenant].engine.start();
        self.submit_local(tenant, initial);
    }

    /// Queue newly materialized tasks of one tenant (engine-local ids).
    fn submit_local(&mut self, tenant: usize, tasks: Vec<TaskId>) {
        for id in tasks {
            self.submit_one(tenant, id);
        }
    }

    /// Queue already-namespaced tasks (crash resubmission paths).
    fn submit_global(&mut self, tasks: Vec<TaskId>) {
        for id in tasks {
            self.submit_one(workload::task_tenant(id), workload::local_task(id));
        }
    }

    /// Queue one task of `tenant`, given by its engine-local id.
    fn submit_one(&mut self, tenant: usize, lid: TaskId) {
        let eng = &self.tenants[tenant].engine;
        let t = eng.task(lid);
        let intermediate: Vec<FileId> = t
            .inputs
            .iter()
            .copied()
            .filter(|f| !eng.file(*f).is_workflow_input())
            .map(|f| workload::ns_file(tenant, f))
            .collect();
        // Schedulers see the oracle's *estimate* of compute seconds,
        // never the truth draw; 0.0 (ignored by every policy) when the
        // uncertainty subsystem is off.
        let est_compute_s = match self.oracle.as_ref() {
            Some(o) => {
                let key = crate::uncertain::type_key(
                    &self.tenants[tenant].workflow_name,
                    t.stage.0 as u32,
                );
                o.estimate_s(key, t.compute.as_secs_f64())
            }
            None => 0.0,
        };
        let rt = ReadyTask {
            id: workload::ns_task(tenant, lid),
            cores: t.cores,
            mem: t.mem,
            rank: eng.rank_of(lid),
            input_bytes: t.input_bytes(eng.files()),
            intermediate_inputs: intermediate,
            submitted_seq: self.submitted_seq,
            tenant,
            est_compute_s,
        };
        // `tenant` and the id's namespace are two encodings of the same
        // fact; policy code reads the field, id-keyed maps the high bits.
        debug_assert_eq!(workload::task_tenant(rt.id), rt.tenant);
        self.submitted_seq += 1;
        let gid = rt.id;
        self.ready_pos.insert(rt.id, self.ready.len());
        self.ready.push(rt);
        self.ready_dead.push(false);
        let now = self.net.now();
        self.tracer.emit(now, || TraceEvent::TaskSubmit { task: gid.0, tenant: tenant as u64 });
    }

    /// Drop tombstoned (started) entries so the schedulers see a dense
    /// slice; submission order — and with it every FIFO tie-break — is
    /// preserved.
    fn compact_ready(&mut self) {
        if self.n_ready_dead == 0 {
            return;
        }
        let mut w = 0;
        for i in 0..self.ready.len() {
            if self.ready_dead[i] {
                continue;
            }
            if w != i {
                self.ready.swap(w, i);
            }
            w += 1;
        }
        self.ready.truncate(w);
        self.ready_dead.clear();
        self.ready_dead.resize(w, false);
        self.n_ready_dead = 0;
        self.ready_pos.clear();
        for (i, rt) in self.ready.iter().enumerate() {
            self.ready_pos.insert(rt.id, i);
        }
    }

    /// Inter-tenant precedence ranks for this iteration (empty on
    /// single-tenant runs — the strategies then behave exactly as on a
    /// single workflow). The ordering itself lives in
    /// [`crate::scheduler::tenant_precedence`] so weight semantics are
    /// unit-testable next to the policies.
    fn tenant_precedence(&self) -> Vec<u64> {
        let tenants: Vec<(SimTime, f64, u64)> =
            self.tenants.iter().map(|t| (t.arrival, t.weight, t.running_cores)).collect();
        crate::scheduler::tenant_precedence(self.cfg.tenant_policy, &tenants)
    }

    /// One scheduling round: a strategy pass, then — with serving
    /// preemption on — evict-and-repeat until no eviction helps. The
    /// per-task preemption cap bounds the loop at #tasks iterations
    /// total across the whole run.
    fn schedule(&mut self) {
        self.schedule_once();
        if !self.cfg.serve.preempt {
            return;
        }
        while let Some(victim) = self.preemption_victim() {
            let now = self.net.now();
            self.preempt_task(victim, now);
            self.schedule_once();
        }
    }

    /// One scheduling iteration: ask the strategy, apply its actions.
    /// (Single pass — the strategies are idempotent and every applied
    /// action triggers a fresh iteration through its completion event.)
    fn schedule_once(&mut self) {
        self.compact_ready();
        let prec = self.tenant_precedence();
        let view = SchedView {
            now: self.net.now(),
            cluster: &self.cluster,
            ready: &self.ready,
            tenant_prec: &prec,
        };
        let w = self.prof_wall.then(std::time::Instant::now);
        // With tracing on, ask the strategy to also explain its picks.
        // The explained path is RNG-identical to the plain one (the
        // default impl and every override are pure observers), so the
        // placement stream — and the fingerprint — cannot move.
        let actions = if self.tracer.enabled() {
            let mut explain = Vec::new();
            let acts = self.scheduler.iterate_explained(&view, &mut self.dps, &mut explain);
            let now = view.now;
            for e in &explain {
                self.tracer.emit(now, || TraceEvent::Decision {
                    task: e.task.0,
                    node: e.node.0,
                    kind: e.kind.label(),
                    candidates: e.candidates,
                    cost: e.cost,
                    affinity: e.affinity,
                    est: e.est,
                });
            }
            acts
        } else {
            self.scheduler.iterate(&view, &mut self.dps)
        };
        if let Some(w) = w {
            self.prof.wall_sched_s += w.elapsed().as_secs_f64();
        }
        self.prof.sched_iterations += 1;
        self.prof.sched_actions += actions.len() as u64;
        for action in actions {
            match action {
                Action::Start { task, node } => {
                    self.start_task(task, node);
                }
                Action::StartCop { task, dst } => {
                    self.start_cop(task, dst);
                }
            }
        }
    }

    /// Pick the task to evict so the highest-precedence ready task can
    /// start, or `None` if no eviction is warranted: the best ready task
    /// must fit on no alive worker, the victim must belong to a strictly
    /// lower-precedence tenant, evicting it must actually make room,
    /// and a task already preempted once is immune (under fair-share,
    /// precedence flips as usage shifts; an unbounded policy could
    /// ping-pong kills forever). Among eligible victims the choice is
    /// by (worst precedence, latest start — least sunk work, highest
    /// id), which is deterministic regardless of map iteration order.
    fn preemption_victim(&mut self) -> Option<TaskId> {
        if self.running.is_empty() {
            return None;
        }
        self.compact_ready();
        if self.ready.is_empty() {
            return None;
        }
        let prec = self.tenant_precedence();
        if prec.is_empty() {
            return None; // single tenant: no one to preempt for
        }
        let view = SchedView {
            now: self.net.now(),
            cluster: &self.cluster,
            ready: &self.ready,
            tenant_prec: &prec,
        };
        let best = view.best_ready()?;
        let (b_cores, b_mem, b_tenant) = (best.cores, best.mem, best.tenant);
        if self.cluster.alive_workers().any(|n| self.cluster.fits(n, b_cores, b_mem)) {
            return None; // it fits somewhere: the next iteration starts it
        }
        let best_prec = prec[b_tenant];
        let mut victim: Option<(u64, SimTime, TaskId)> = None;
        for (&t, r) in &self.running {
            if workload::is_spec_task(t) {
                // Backups resolve through the speculation path (win or
                // kill), never through tenant preemption — evicting one
                // would resubmit it as a second canonical copy.
                continue;
            }
            let vp = prec[workload::task_tenant(t)];
            if vp <= best_prec {
                continue; // only strictly lower-precedence tenants yield
            }
            if self.preempt_counts.get(&t).copied().unwrap_or(0) >= 1 {
                continue;
            }
            let node = self.cluster.node(r.node);
            if !node.alive
                || node.free_cores + r.cores < b_cores
                || node.free_mem.0 + r.mem.0 < b_mem.0
            {
                continue; // eviction would not make room
            }
            let key = (vp, r.started, t);
            if victim.is_none_or(|v| key > v) {
                victim = Some(key);
            }
        }
        victim.map(|(_, _, t)| t)
    }

    /// Evict a running task for a higher-precedence one. Like a crash
    /// kill, the partial work is wasted and the task resubmits (its
    /// in-flight `ComputeDone`, if any, dies on the attempt check) —
    /// but the node survives, so its capacity ledger is released here.
    /// Partial outputs cannot exist (outputs register only at
    /// completion); the DPS release below is a defensive invariant so a
    /// preempted task can never leave replicas behind.
    fn preempt_task(&mut self, task: TaskId, now: SimTime) {
        let r = self.running.remove(&task).expect("preemption victim");
        for f in self.flows_of_task(task) {
            let _ = self.disown_flow(f);
            self.net.cancel(f);
        }
        self.ckpt_pending.remove(&task);
        let wall = (now - r.started).as_secs_f64();
        self.cpu_core_seconds += wall * r.cores as f64;
        self.node_cpu_seconds[r.node.0] += wall * r.cores as f64;
        // Same wasted/salvaged split as `kill_running`: an evicted task
        // also resumes from its last committed checkpoint.
        let salvaged = r.ckpt_wall.min(wall);
        self.wasted_core_seconds += (wall - salvaged) * r.cores as f64;
        self.salvaged_core_seconds += salvaged * r.cores as f64;
        self.preempted_core_seconds += wall * r.cores as f64;
        self.n_preempted += 1;
        *self.preempt_counts.entry(task).or_insert(0) += 1;
        self.tasks_rerun += 1;
        self.retries.remove(&task);
        self.cluster.release(r.node, r.cores, r.mem);
        let tn = workload::task_tenant(task);
        self.tracer.emit(now, || TraceEvent::TaskPreempt {
            task: task.0,
            node: r.node.0,
            tenant: tn as u64,
        });
        self.tenants[tn].running_cores -= r.cores as u64;
        if self.scheduler.uses_local_data() {
            let lid = workload::local_task(task);
            for &(f, size) in &self.tenants[tn].engine.task(lid).outputs {
                for node in self.dps.release_file(workload::ns_file(tn, f)) {
                    self.node_replica_bytes[node.0] -= size.as_f64();
                }
            }
        }
        self.submit_global(vec![task]);
    }

    fn start_task(&mut self, task: TaskId, node: NodeId) -> bool {
        let pos = match self.ready_pos.get(&task) {
            Some(&p) => p,
            None => return false, // already started (stale action)
        };
        // A speculative copy must land on a *different* node than its
        // straggling original — co-located backups hit the same slow
        // hardware and waste cores. The scheduler is oblivious to the
        // pairing, so the guard lives at start time: the action is
        // dropped and the backup stays queued for a later iteration.
        if self.cfg.uncertain.speculate {
            let peer = if workload::is_spec_task(task) {
                workload::canonical_task(task)
            } else {
                workload::spec_task(task)
            };
            if self.running.get(&peer).is_some_and(|r| r.node == node) {
                return false;
            }
        }
        debug_assert!(!self.ready_dead[pos] && self.ready[pos].id == task);
        let (cores, mem) = (self.ready[pos].cores, self.ready[pos].mem);
        self.ready_dead[pos] = true;
        self.n_ready_dead += 1;
        self.ready_pos.remove(&task);
        assert!(
            self.cluster.fits(node, cores, mem),
            "scheduler over-subscribed node {node:?} for task {task:?}"
        );
        self.cluster.reserve(node, cores, mem);
        let now = self.net.now();
        self.first_start.get_or_insert(now);
        let tn = workload::task_tenant(task);
        let lid = workload::local_task(task);
        self.tenants[tn].first_start.get_or_insert(now);
        self.tenants[tn].running_cores += cores as u64;
        self.tracer.emit(now, || TraceEvent::PhaseStart {
            task: task.0,
            node: node.0,
            phase: "stage-in",
        });

        // Mark used COPs: any not-yet-used completed COP targeting this
        // node whose files intersect the inputs — regardless of which
        // task the COP was created for. Inputs are engine-local;
        // everything shared (COPs, DPS, DFS, flows) uses namespaced ids.
        if let Some(mut candidates) = self.unused_cops_by_node.remove(&node) {
            let inputs_g: FastSet<FileId> = self.tenants[tn]
                .engine
                .task(lid)
                .inputs
                .iter()
                .map(|&f| workload::ns_file(tn, f))
                .collect();
            candidates.retain(|cop| {
                if cop.files.iter().any(|f| inputs_g.contains(f)) {
                    self.n_cops_used += 1;
                    let cop_id = cop.id;
                    self.tracer.emit(now, || TraceEvent::CopUsed {
                        cop: cop_id.0,
                        task: task.0,
                        node: node.0,
                    });
                    false
                } else {
                    true
                }
            });
            if !candidates.is_empty() {
                self.unused_cops_by_node.insert(node, candidates);
            }
        }

        let n_flows = self.issue_stage_in_flows(task, node);

        self.exec_seq += 1;
        self.running.insert(
            task,
            Running {
                node,
                phase: Phase::StageIn,
                pending_flows: n_flows,
                started: now,
                compute_started: now,
                attempt: self.exec_seq,
                cores,
                mem,
                rate: 0.0,
                base_offset: 0.0,
                ckpt_wall: 0.0,
                unc_tfac: 1.0,
            },
        );
        if n_flows == 0 {
            self.begin_compute(task, now);
        }
        true
    }

    /// Issue the stage-in flows for `task` on `node` — shared by fresh
    /// starts and crash-time phase restarts so the two paths can never
    /// drift: local-disk reads for DPS-prepared intermediates in WOW
    /// mode, DFS reads otherwise. Returns the number of flows issued.
    fn issue_stage_in_flows(&mut self, task: TaskId, node: NodeId) -> usize {
        let local_mode = self.scheduler.uses_local_data();
        let tn = workload::task_tenant(task);
        let lid = workload::local_task(task);
        // Indexed walk instead of cloning the input list: the loop body
        // needs `&mut self` (flows, ownership records), so a borrow of
        // the engine cannot live across it.
        let n_inputs = self.tenants[tn].engine.task(lid).inputs.len();
        let mut n_flows = 0;
        for ii in 0..n_inputs {
            let eng = &self.tenants[tn].engine;
            let lf = eng.task(lid).inputs[ii];
            let size = eng.file(lf).size;
            let is_input = eng.file(lf).is_workflow_input();
            let gf = workload::ns_file(tn, lf);
            if local_mode && !is_input {
                // Intermediate input: must be local (node is prepared).
                debug_assert!(
                    self.dps.is_prepared(&[gf], node),
                    "task {task:?} started on unprepared node {node:?} (file {gf:?})"
                );
                let n = self.cluster.node(node);
                let id = self.net.add_flow(size, vec![n.disk_read]);
                self.own_flow(id, FlowOwner::StageIn(task));
                n_flows += 1;
            } else {
                // Cross-tenant dedup: a reference file whose content
                // some tenant already staged onto this node is read from
                // local disk instead of re-fetched through the DFS.
                if is_input
                    && self.cfg.serve.dedup
                    && self.dps.shared_replica(gf, node).is_some()
                {
                    self.dedup_bytes += size;
                    let n = self.cluster.node(node);
                    let id = self.net.add_flow(size, vec![n.disk_read]);
                    self.own_flow(id, FlowOwner::StageIn(task));
                    n_flows += 1;
                    continue;
                }
                for part in self.dfs.read(gf, size, node, &self.cluster, &mut self.rng) {
                    let id = self.net.add_flow(part.bytes, part.resources);
                    self.own_flow(id, FlowOwner::StageIn(task));
                    n_flows += 1;
                }
            }
        }
        n_flows
    }

    fn begin_compute(&mut self, task: TaskId, now: SimTime) {
        let r = self.running.get_mut(&task).expect("running");
        r.phase = Phase::Compute;
        r.compute_started = now;
        let (node, attempt) = (r.node, r.attempt);
        self.tracer.emit(now, || TraceEvent::PhaseStart {
            task: task.0,
            node: node.0,
            phase: "compute",
        });
        // Cross-tenant dedup: the reference inputs just staged onto
        // `node` become shareable replicas for later arrivals. Their
        // bytes are *not* counted as replica storage — the DFS already
        // accounts the staged copy; the DPS entry only records where
        // the content sits. Idempotent across compute retries.
        if self.cfg.serve.dedup {
            let tn = workload::task_tenant(task);
            let lid = workload::local_task(task);
            for &lf in &self.tenants[tn].engine.task(lid).inputs {
                if !self.tenants[tn].engine.file(lf).is_workflow_input() {
                    continue;
                }
                let gf = workload::ns_file(tn, lf);
                if !self.dps.locations(gf).contains(&node) {
                    let size = self.tenants[tn].engine.file(lf).size;
                    self.dps.register_output(gf, size, node);
                }
            }
        }
        // Heterogeneous speeds: slower nodes stretch compute (§VIII).
        let speed = self.cluster.node(node).spec.speed;
        // Retried attempts run inflated (DynamicCloudSim's runtime
        // variation on re-execution), under the configurable backoff
        // model — at the defaults `retry_factor` reproduces the flat
        // `retry_inflation^tries` bit-exactly. The salt is pure
        // arithmetic over (seed, task, attempt): no RNG stream.
        let tries = self.retries.get(&task).copied().unwrap_or(0);
        let salt = self.cfg.seed ^ task.0.rotate_left(17) ^ attempt;
        let infl = self.cfg.fault.retry_factor(tries, salt);
        let tn = workload::task_tenant(task);
        let base = self.tenants[tn].engine.task(workload::local_task(task)).compute;
        // Runtime uncertainty: the executor runs the *truth* — the
        // nominal duration times a per-attempt lognormal draw, divided
        // by the node's dynamic speed class. Both factors are exactly
        // 1.0 when the subsystem is off (multiplying a finite positive
        // f64 by 1.0 is bit-exact, and the fast branch below still
        // fires), so disabled runs reproduce the pre-uncertainty bits.
        let (tfac, uspeed) = if self.cfg.uncertain.enabled() {
            let sigma = self.cfg.uncertain.noise_sigma;
            let tf = crate::uncertain::truth_factor(sigma, self.cfg.seed, task.0, attempt);
            (tf, self.unc_speed_of(node))
        } else {
            (1.0, 1.0)
        };
        // Checkpoint/restart: resume from the durably committed compute
        // progress instead of t=0. `ckpt_committed` can only be
        // non-empty when checkpointing is on, so the `done == 0` branch
        // below is the exact pre-resilience duration expression.
        let done = self.ckpt_committed.get(&task).copied().unwrap_or(0.0);
        let dur = if done > 0.0 {
            let remaining = (base.as_secs_f64() - done).max(0.0);
            SimTime::from_secs_f64(remaining * tfac / (speed * uspeed) * infl)
        } else if speed == 1.0 && infl == 1.0 && tfac == 1.0 && uspeed == 1.0 {
            base
        } else {
            SimTime::from_secs_f64(base.as_secs_f64() * tfac / (speed * uspeed) * infl)
        };
        if self.cfg.resil.checkpoint_every_s > 0.0 {
            let remaining = (base.as_secs_f64() - done).max(0.0);
            let r = self.running.get_mut(&task).expect("running");
            r.base_offset = done;
            r.rate = if dur > SimTime::ZERO { remaining / dur.as_secs_f64() } else { 0.0 };
            let iv = SimTime::from_secs_f64(self.cfg.resil.checkpoint_every_s);
            if iv < dur {
                self.events.push(now + iv, Event::Checkpoint(task, attempt));
            }
        }
        self.events.push(now + dur, Event::ComputeDone(task, attempt));
        if self.cfg.uncertain.enabled() {
            // Remember the truth factor so the re-estimator can observe
            // it on success, and arm the straggler probe: fire when the
            // attempt has run `spec_factor`× its *estimated* wall time.
            self.running.get_mut(&task).expect("running").unc_tfac = tfac;
            if self.cfg.uncertain.speculate && !workload::is_spec_task(task) {
                let remaining = (base.as_secs_f64() - done).max(0.0);
                let lid = workload::local_task(task);
                let key = self.type_key_of(tn, lid);
                let fac = self.oracle.as_ref().map(|o| o.estimate_factor(key)).unwrap_or(1.0);
                let est_wall = remaining * fac / (speed * uspeed) * infl;
                let wait = (est_wall * self.cfg.uncertain.spec_factor).max(1.0);
                let at = now + SimTime::from_secs_f64(wait);
                self.events.push(at, Event::StragglerCheck(task, attempt));
            }
        }
    }

    /// A checkpoint tick fired. If the attempt is still computing, cut
    /// its current progress and persist `checkpoint_gb` through the DFS
    /// (real flows on the resolved path); the cut commits only when all
    /// flows land (see [`FlowOwner::Checkpoint`]). The cadence re-arms
    /// itself until the attempt leaves the compute phase.
    fn on_checkpoint(&mut self, task: TaskId, attempt: u64, now: SimTime) {
        let valid = matches!(
            self.running.get(&task),
            Some(r) if r.attempt == attempt && r.phase == Phase::Compute
        );
        if !valid {
            return;
        }
        let iv = SimTime::from_secs_f64(self.cfg.resil.checkpoint_every_s);
        self.events.push(now + iv, Event::Checkpoint(task, attempt));
        if self.ckpt_pending.contains_key(&task) {
            return; // previous write still draining; skip this tick
        }
        let (node, cut_wall, base_done) = {
            let r = &self.running[&task];
            let w = (now - r.compute_started).as_secs_f64();
            (r.node, w, r.base_offset + w * r.rate)
        };
        let bytes = Bytes::from_gb(self.cfg.resil.checkpoint_gb);
        let mut n_flows = 0;
        for part in self.dfs.write(ckpt_file(task), bytes, node, &self.cluster, &mut self.rng) {
            let id = self.net.add_flow(part.bytes, part.resources);
            self.own_flow(id, FlowOwner::Checkpoint(task));
            n_flows += 1;
        }
        if n_flows == 0 {
            self.commit_checkpoint(task, base_done, cut_wall, bytes, now);
        } else {
            self.ckpt_pending.insert(
                task,
                CkptPending { attempt, flows: n_flows, base_done, cut_wall, bytes },
            );
        }
    }

    /// All flows of a checkpoint landed while its attempt still
    /// computes: the cut becomes the task's durable restart point.
    fn commit_checkpoint(
        &mut self,
        task: TaskId,
        base_done: f64,
        cut_wall: f64,
        bytes: Bytes,
        now: SimTime,
    ) {
        self.ckpt_committed.insert(task, base_done);
        let node = {
            let r = self.running.get_mut(&task).expect("committing for a running task");
            r.ckpt_wall = cut_wall;
            r.node
        };
        self.n_checkpoints += 1;
        self.checkpoint_bytes += bytes;
        self.tracer.emit(now, || TraceEvent::Checkpoint {
            task: task.0,
            node: node.0,
            bytes: bytes.as_u64(),
        });
    }

    /// Drop an in-flight checkpoint write (compute ended or the task
    /// died): cancel its remaining flows without committing the cut.
    fn abort_checkpoint(&mut self, task: TaskId) {
        if self.ckpt_pending.remove(&task).is_none() {
            return;
        }
        for f in self.flows_of_task(task) {
            if matches!(self.flow_owner.get(&f), Some(FlowOwner::Checkpoint(_))) {
                let _ = self.disown_flow(f);
                self.net.cancel(f);
            }
        }
    }

    /// Sample whether the compute attempt that just ended was an
    /// injected transient failure. Bounded: after `max_task_retries`
    /// failures the task runs clean, so workflows always terminate.
    fn compute_attempt_fails(&mut self, task: TaskId) -> bool {
        let p = self.cfg.fault.task_fail_prob;
        if p <= 0.0 {
            return false;
        }
        let tries = self.retries.get(&task).copied().unwrap_or(0);
        tries < self.cfg.fault.max_task_retries && self.fault_rng.next_f64() < p
    }

    /// The attempt failed: account the wasted cycles and rerun compute
    /// on the same node (inputs are still staged there).
    fn retry_compute(&mut self, task: TaskId, now: SimTime) {
        *self.retries.entry(task).or_insert(0) += 1;
        self.task_failures += 1;
        let (cores, wasted_s) = {
            let r = &self.running[&task];
            (r.cores, (now - r.compute_started).as_secs_f64())
        };
        self.wasted_core_seconds += wasted_s * cores as f64;
        self.tracer.emit(now, || TraceEvent::TaskRetry { task: task.0 });
        self.begin_compute(task, now);
    }

    fn start_stage_out(&mut self, task: TaskId, now: SimTime) {
        // Compute is done: an in-flight checkpoint write is pointless.
        if self.cfg.resil.checkpoint_every_s > 0.0 {
            self.abort_checkpoint(task);
        }
        let local_mode = self.scheduler.uses_local_data();
        let node = self.running[&task].node;
        self.tracer.emit(now, || TraceEvent::PhaseStart {
            task: task.0,
            node: node.0,
            phase: "stage-out",
        });
        let tn = workload::task_tenant(task);
        let lid = workload::local_task(task);
        // Indexed walk, mirroring `issue_stage_in_flows`: no per-task
        // clone of the output list on this hot path.
        let n_out = self.tenants[tn].engine.task(lid).outputs.len();
        let mut n_flows = 0;
        for oi in 0..n_out {
            let (f, size) = self.tenants[tn].engine.task(lid).outputs[oi];
            if local_mode {
                let n = self.cluster.node(node);
                let id = self.net.add_flow(size, vec![n.disk_write]);
                self.own_flow(id, FlowOwner::StageOut(task));
                n_flows += 1;
            } else {
                let gf = workload::ns_file(tn, f);
                for part in self.dfs.write(gf, size, node, &self.cluster, &mut self.rng) {
                    let id = self.net.add_flow(part.bytes, part.resources);
                    self.own_flow(id, FlowOwner::StageOut(task));
                    n_flows += 1;
                }
            }
        }
        let r = self.running.get_mut(&task).expect("running");
        r.phase = Phase::StageOut;
        r.pending_flows = n_flows;
        if n_flows == 0 {
            self.complete_task(task, now);
        }
    }

    /// Returns true if the completion should trigger a scheduling
    /// iteration.
    fn flow_finished(&mut self, owner: FlowOwner, now: SimTime) -> bool {
        match owner {
            FlowOwner::StageIn(task) => {
                let r = self.running.get_mut(&task).expect("running task");
                debug_assert_eq!(r.phase, Phase::StageIn);
                r.pending_flows -= 1;
                if r.pending_flows == 0 {
                    self.begin_compute(task, now);
                }
                false
            }
            FlowOwner::StageOut(task) => {
                let r = self.running.get_mut(&task).expect("running task");
                debug_assert_eq!(r.phase, Phase::StageOut);
                r.pending_flows -= 1;
                if r.pending_flows == 0 {
                    self.complete_task(task, now);
                    return true;
                }
                false
            }
            // Re-replication finished; nothing waits on it.
            FlowOwner::Recovery => false,
            FlowOwner::Checkpoint(task) => {
                if let Some(p) = self.ckpt_pending.get_mut(&task) {
                    p.flows -= 1;
                    if p.flows == 0 {
                        let p = self.ckpt_pending.remove(&task).expect("pending checkpoint");
                        let valid = matches!(
                            self.running.get(&task),
                            Some(r) if r.attempt == p.attempt && r.phase == Phase::Compute
                        );
                        if valid {
                            self.commit_checkpoint(task, p.base_done, p.cut_wall, p.bytes, now);
                        }
                    }
                }
                false
            }
        }
    }

    fn complete_task(&mut self, task: TaskId, now: SimTime) {
        let r = self.running.remove(&task).expect("running");
        self.cluster.release(r.node, r.cores, r.mem);
        self.retries.remove(&task);
        self.ckpt_committed.remove(&task);
        let wall = (now - r.started).as_secs_f64();
        self.cpu_core_seconds += wall * r.cores as f64;
        self.node_cpu_seconds[r.node.0] += wall * r.cores as f64;
        self.last_finish = now;
        self.tasks_done += 1;
        self.tracer.emit(now, || TraceEvent::TaskComplete { task: task.0, node: r.node.0 });
        let tn = workload::task_tenant(task);
        let lid = workload::local_task(task);
        self.tenants[tn].last_finish = now;
        self.tenants[tn].running_cores -= r.cores as u64;

        // Outputs become visible; in WOW mode they are DPS-managed local
        // files.
        if self.scheduler.uses_local_data() {
            for &(f, size) in &self.tenants[tn].engine.task(lid).outputs {
                self.dps.register_output(workload::ns_file(tn, f), size, r.node);
                self.node_replica_bytes[r.node.0] += size.as_f64();
            }
            self.update_peak();
            // k-resilient hedging: every fresh intermediate gets
            // replicas across 1 + hedge_k failure domains.
            if self.cfg.resil.hedge_k > 0 {
                let n_out = self.tenants[tn].engine.task(lid).outputs.len();
                for oi in 0..n_out {
                    let f = self.tenants[tn].engine.task(lid).outputs[oi].0;
                    self.ensure_hedged(workload::ns_file(tn, f), None);
                }
            }
        }
        let newly_ready = self.tenants[tn].engine.complete_task(lid);
        // Replica GC (§III-A): free intermediate files no task can read
        // any more.
        if self.cfg.replica_gc && self.scheduler.uses_local_data() {
            for f in self.tenants[tn].engine.take_dead_files() {
                // Dedup'd reference replicas are shared across tenants
                // (and never counted as replica storage): one tenant's
                // death must not release them.
                if self.tenants[tn].engine.file(f).is_workflow_input() {
                    continue;
                }
                let size = self.tenants[tn].engine.file(f).size.as_f64();
                for node in self.dps.release_file(workload::ns_file(tn, f)) {
                    self.node_replica_bytes[node.0] -= size;
                }
            }
        } else {
            self.tenants[tn].engine.take_dead_files();
        }
        self.submit_local(tn, newly_ready);
        if !self.tenants[tn].finished && self.tenants[tn].engine.all_done() {
            self.tenant_finished(tn);
        }
    }

    fn update_peak(&mut self) {
        let total: f64 = self.node_replica_bytes.iter().sum();
        if total > self.peak_replica_bytes {
            self.peak_replica_bytes = total;
        }
    }

    fn start_cop(&mut self, task: TaskId, dst: NodeId) -> bool {
        // The scheduler checked feasibility; re-plan for fresh sources.
        // The input list is read in place from the ready entry (`dps`
        // and `ready` are disjoint fields) — no per-COP clone.
        let pos = match self.ready_pos.get(&task) {
            Some(&p) => p,
            None => return false, // task started in the same batch
        };
        let plan = match self.dps.plan(&self.ready[pos].intermediate_inputs, dst) {
            Some(p) => p,
            None => return false,
        };
        let cop = self.dps.start_cop(task, dst, plan);
        self.tasks_with_cops.insert(task);
        // Setup latency before bytes move; the COP occupies its c_node /
        // c_task slots for the whole window (reserved at creation).
        let launch_at = self.net.now() + SimTime::from_secs_f64(self.cfg.cop_setup_s);
        let now = self.net.now();
        let (cid, total) = (cop.id, cop.total_bytes());
        self.tracer.emit(now, || TraceEvent::CopStart {
            cop: cid.0,
            task: task.0,
            dst: dst.0,
            bytes: total.as_u64(),
        });
        self.pending_cops.insert(cid, cop);
        self.events.push(launch_at, Event::CopLaunch(cid));
        // k-resilient hedging: a task-prep COP marks its files hot;
        // make sure each spans enough failure domains (the just-planned
        // destination counts as about-to-be-covered).
        if self.cfg.resil.hedge_k > 0 {
            for i in 0..self.ready[pos].intermediate_inputs.len() {
                let f = self.ready[pos].intermediate_inputs[i];
                self.ensure_hedged(f, Some(dst));
            }
        }
        true
    }

    fn cop_finished(&mut self, id: CopId) {
        let cop = self.dps.complete_cop(id);
        for (_, _, size) in &cop.parts {
            self.node_replica_bytes[cop.dst.0] += size.as_f64();
        }
        self.update_peak();
        let now = self.net.now();
        self.tracer.emit(now, || TraceEvent::CopFinish {
            cop: id.0,
            dst: cop.dst.0,
            bytes: cop.total_bytes().as_u64(),
        });
        // A landed hedge is accounted separately and skips usefulness
        // attribution — it exists to survive a domain failure, not to
        // prepare a task.
        if let Some(file) = self.hedge_cop_ids.remove(&id) {
            self.n_hedge_cops += 1;
            self.hedge_bytes += cop.total_bytes();
            self.forget_hedge_in_flight(file, cop.dst);
            return;
        }
        let files = cop.parts.iter().map(|(f, _, _)| *f).collect();
        self.unused_cops_by_node.entry(cop.dst).or_default().push(CompletedCop { id, files });
    }

    /// Ensure `file`'s replicas — plus hedges already in flight and an
    /// optional about-to-land destination — span at least `1 + hedge_k`
    /// distinct failure domains, launching the cheapest domain-diverse
    /// hedge COP per missing domain. Enabled-only (`hedge_k ≥ 1`).
    fn ensure_hedged(&mut self, file: FileId, landing: Option<NodeId>) {
        if !self.scheduler.uses_local_data() {
            return;
        }
        let target = 1 + self.cfg.resil.hedge_k as usize;
        loop {
            let mut covered: Vec<NodeId> = self.hedged.get(&file).cloned().unwrap_or_default();
            covered.extend(landing);
            let domains: FastSet<usize> = self
                .dps
                .locations(file)
                .iter()
                .chain(covered.iter())
                .map(|n| self.dps.domain_of(*n))
                .collect();
            if domains.is_empty() || domains.len() >= target {
                return;
            }
            let candidates: Vec<NodeId> = self.cluster.alive_workers().collect();
            let Some((dst, plan)) = self.dps.plan_hedge(file, &candidates, &covered) else {
                return;
            };
            self.launch_hedge(file, dst, plan);
        }
    }

    /// Launch one hedge COP through the regular COP machinery (setup
    /// latency, LCS flows, c_node occupancy) under the sentinel task.
    fn launch_hedge(&mut self, file: FileId, dst: NodeId, plan: CopPlan) {
        let cop = self.dps.start_cop(HEDGE_TASK, dst, plan);
        let now = self.net.now();
        let (cid, total) = (cop.id, cop.total_bytes());
        self.tracer.emit(now, || TraceEvent::CopStart {
            cop: cid.0,
            task: HEDGE_TASK.0,
            dst: dst.0,
            bytes: total.as_u64(),
        });
        self.tracer.emit(now, || TraceEvent::HedgeCopy {
            cop: cid.0,
            file: file.0,
            dst: dst.0,
            bytes: total.as_u64(),
        });
        self.hedge_cop_ids.insert(cid, file);
        self.hedged.entry(file).or_default().push(dst);
        let launch_at = now + SimTime::from_secs_f64(self.cfg.cop_setup_s);
        self.pending_cops.insert(cid, cop);
        self.events.push(launch_at, Event::CopLaunch(cid));
    }

    /// Drop the in-flight record of a hedge toward `dst` (landed or
    /// aborted).
    fn forget_hedge_in_flight(&mut self, file: FileId, dst: NodeId) {
        if let Some(v) = self.hedged.get_mut(&file) {
            v.retain(|n| *n != dst);
            if v.is_empty() {
                self.hedged.remove(&file);
            }
        }
    }

    /// A COP was aborted: if it was a hedge, clean its tracking so the
    /// domain can be re-hedged later.
    fn note_cop_aborted(&mut self, id: CopId, dst: NodeId) {
        if let Some(file) = self.hedge_cop_ids.remove(&id) {
            self.forget_hedge_in_flight(file, dst);
        }
    }

    // ---- runtime uncertainty ---------------------------------------
    //
    // Everything below is dead code on a default config: the single
    // call site in the `ComputeDone` handler early-returns before any
    // state is touched, the probe/degrade events are never scheduled,
    // and no method draws randomness (the truth factor is a pure hash).

    /// A compute attempt finished successfully: feed its truth factor
    /// to the re-estimator and, if it is one half of an open
    /// speculative race, resolve the race *before* stage-out — so the
    /// loser never writes outputs into the DPS or the engine.
    fn on_compute_success(&mut self, task: TaskId, now: SimTime) {
        if !self.cfg.uncertain.enabled() {
            return;
        }
        let tn = workload::task_tenant(task);
        let lid = workload::local_task(task);
        let key = self.type_key_of(tn, lid);
        let tfac = self.running[&task].unc_tfac;
        let (err, est) = self.oracle.as_mut().expect("oracle").observe(key, tfac);
        self.tracer.emit(now, || TraceEvent::EstimateUpdate { task: task.0, err, est });
        if self.cfg.uncertain.speculate {
            self.resolve_speculation(task, now);
        }
    }

    /// First successful finisher of a speculative pair wins; the peer
    /// is killed and its partial work written off as speculation waste.
    fn resolve_speculation(&mut self, task: TaskId, now: SimTime) {
        let canon = workload::canonical_task(task);
        if !self.spec_pending.remove(&canon) {
            return; // no open race for this task
        }
        let peer = if task == canon { workload::spec_task(canon) } else { canon };
        self.kill_spec_peer(peer, now);
        if task != canon {
            // The backup beat the straggler: it carries on under its
            // spec id (completion maps back to the canonical engine
            // task via `local_task`).
            self.n_spec_wins += 1;
            let node = self.running[&task].node;
            self.tracer.emit(now, || TraceEvent::SpeculativeWin { task: canon.0, node: node.0 });
        }
    }

    /// Kill the losing copy of a speculative race: cancel its flows,
    /// release its resources, invalidate any partial outputs in the
    /// DPS, and account the burned core-seconds as speculation waste.
    /// The loser is *not* resubmitted — the winner covers the task. A
    /// still-queued loser is tombstoned instead.
    fn kill_spec_peer(&mut self, peer: TaskId, now: SimTime) {
        if let Some(r) = self.running.remove(&peer) {
            for f in self.flows_of_task(peer) {
                let _ = self.disown_flow(f);
                self.net.cancel(f);
            }
            self.ckpt_pending.remove(&peer);
            self.ckpt_committed.remove(&peer);
            self.retries.remove(&peer);
            let wall = (now - r.started).as_secs_f64();
            self.cpu_core_seconds += wall * r.cores as f64;
            self.node_cpu_seconds[r.node.0] += wall * r.cores as f64;
            self.spec_wasted_core_seconds += wall * r.cores as f64;
            // A loser killed *by* a crash has no ledger to return — the
            // node's capacity resets wholesale on recovery.
            if self.cluster.node(r.node).alive {
                self.cluster.release(r.node, r.cores, r.mem);
            }
            let tn = workload::task_tenant(peer);
            self.tenants[tn].running_cores -= r.cores as u64;
            // Defensive DPS invalidation, mirroring `preempt_task`:
            // outputs register only at completion, so nothing should be
            // here — but a loser must never leave replicas behind.
            if self.scheduler.uses_local_data() {
                let lid = workload::local_task(peer);
                for &(f, size) in &self.tenants[tn].engine.task(lid).outputs {
                    for node in self.dps.release_file(workload::ns_file(tn, f)) {
                        self.node_replica_bytes[node.0] -= size.as_f64();
                    }
                }
            }
            self.tracer.emit(now, || TraceEvent::SpeculativeLoss {
                task: peer.0,
                node: r.node.0,
                ran: true,
            });
        } else if let Some(&pos) = self.ready_pos.get(&peer) {
            self.ready_dead[pos] = true;
            self.n_ready_dead += 1;
            self.ready_pos.remove(&peer);
            self.tracer.emit(now, || TraceEvent::SpeculativeLoss {
                task: peer.0,
                node: 0,
                ran: false,
            });
        }
    }

    /// The straggler probe fired for a computing attempt that has now
    /// run `spec_factor`× its estimated wall time. Launch a backup copy
    /// through the regular ready queue if the evidence supports it:
    /// siblings of the same task type have finished (the estimate is
    /// grounded in observations, not just the static bias) and another
    /// alive worker exists to host it. Returns whether a scheduling
    /// pass is warranted.
    fn on_straggler_check(&mut self, task: TaskId, attempt: u64, now: SimTime) -> bool {
        let valid = matches!(
            self.running.get(&task),
            Some(r) if r.attempt == attempt && r.phase == Phase::Compute
        );
        if !valid || self.spec_pending.contains(&task) {
            return false;
        }
        let tn = workload::task_tenant(task);
        let lid = workload::local_task(task);
        let key = self.type_key_of(tn, lid);
        let cur = self.running[&task].node;
        if self.oracle.as_ref().expect("oracle").observations(key) == 0 {
            // No finished sibling to compare against — the attempt may
            // be long because the *type* is long. Re-probe later.
            let base = self.tenants[tn].engine.task(lid).compute.as_secs_f64();
            let wait = (base * self.cfg.uncertain.spec_factor).max(1.0);
            let at = now + SimTime::from_secs_f64(wait);
            self.events.push(at, Event::StragglerCheck(task, attempt));
            return false;
        }
        if !self.cluster.alive_workers().any(|n| n != cur) {
            return false; // nowhere else to run a backup
        }
        let spec = workload::spec_task(task);
        let eng = &self.tenants[tn].engine;
        let t = eng.task(lid);
        let intermediate: Vec<FileId> = t
            .inputs
            .iter()
            .copied()
            .filter(|f| !eng.file(*f).is_workflow_input())
            .map(|f| workload::ns_file(tn, f))
            .collect();
        let est_compute_s = self
            .oracle
            .as_ref()
            .expect("oracle")
            .estimate_s(key, t.compute.as_secs_f64());
        let rt = ReadyTask {
            id: spec,
            cores: t.cores,
            mem: t.mem,
            rank: eng.rank_of(lid),
            input_bytes: t.input_bytes(eng.files()),
            intermediate_inputs: intermediate,
            submitted_seq: self.submitted_seq,
            tenant: tn,
            est_compute_s,
        };
        self.submitted_seq += 1;
        self.ready_pos.insert(spec, self.ready.len());
        self.ready.push(rt);
        self.ready_dead.push(false);
        self.spec_pending.insert(task);
        self.n_spec_launches += 1;
        self.tracer.emit(now, || TraceEvent::SpeculativeLaunch { task: task.0, spec: spec.0 });
        true
    }

    /// Effective uncertainty speed multiplier of a node: its static
    /// class times the degradation factor while a window is open.
    /// Exactly 1.0 on disabled runs (the class table is empty).
    fn unc_speed_of(&self, node: NodeId) -> f64 {
        if self.unc_class.is_empty() {
            return 1.0;
        }
        let mut s = self.unc_class[node.0];
        if self.unc_degraded[node.0] > 0 {
            s *= self.cfg.uncertain.degrade_factor;
        }
        s
    }

    /// The oracle's task-type key for one engine-local task: workflow
    /// name × stage index.
    fn type_key_of(&self, tenant: usize, lid: TaskId) -> u64 {
        let t = &self.tenants[tenant];
        crate::uncertain::type_key(&t.workflow_name, t.engine.task(lid).stage.0 as u32)
    }

    /// A degradation window opens on a worker. Attempts already
    /// computing keep their stretched-or-not duration — degradation
    /// applies at compute start, like the static speed classes.
    fn on_unc_degrade(&mut self, node: usize, now: SimTime) {
        self.unc_degraded[node] += 1;
        self.n_unc_degrades += 1;
        let factor = self.cfg.uncertain.degrade_factor;
        self.tracer.emit(now, || TraceEvent::NodeDegrade { node, factor, restore: false });
    }

    /// One degradation window on the worker ends.
    fn on_unc_restore(&mut self, node: usize, now: SimTime) {
        self.unc_degraded[node] -= 1;
        self.tracer.emit(now, || TraceEvent::NodeDegrade { node, factor: 1.0, restore: true });
    }

    // ---- fault injection & recovery --------------------------------

    /// Apply one injected fault. Returns true if a scheduling iteration
    /// should follow.
    fn apply_fault(&mut self, ev: FaultEvent, now: SimTime) -> bool {
        let (kind, subject) = match ev {
            FaultEvent::NodeCrash(n) => ("node-crash", n.0 as u64),
            FaultEvent::NodeRecover(n) => ("node-recover", n.0 as u64),
            FaultEvent::LinkDegrade(n) => ("link-degrade", n.0 as u64),
            FaultEvent::LinkRestore(n) => ("link-restore", n.0 as u64),
            FaultEvent::RackLinkDegrade(r) => ("rack-degrade", r as u64),
            FaultEvent::RackLinkRestore(r) => ("rack-restore", r as u64),
        };
        self.tracer.emit(now, || TraceEvent::Fault { kind, subject });
        match ev {
            FaultEvent::NodeCrash(node) => {
                self.on_node_crash(node, now);
                true
            }
            FaultEvent::NodeRecover(node) => {
                self.on_node_recover(node);
                true
            }
            FaultEvent::LinkDegrade(node) => {
                self.n_degrades += 1;
                *self.degraded.entry(node).or_insert(0) += 1;
                let factor = self.cfg.fault.degrade_factor.max(1e-6);
                let n = self.cluster.node(node);
                let cap = Bandwidth(n.spec.link.bytes_per_sec() * factor);
                let (up, down) = (n.nic_up, n.nic_down);
                self.net.set_capacity(up, cap);
                self.net.set_capacity(down, cap);
                // Topology pricing sees the degraded NIC (no-op on flat).
                self.dps.note_link_change(node, cap.bytes_per_sec());
                false
            }
            FaultEvent::LinkRestore(node) => {
                // Overlapping brownouts on one node: only the last
                // restore brings the link back to spec.
                let left = self.degraded.get_mut(&node).expect("restore without degrade");
                *left -= 1;
                if *left > 0 {
                    return false;
                }
                self.degraded.remove(&node);
                let n = self.cluster.node(node);
                let (link, up, down) = (n.spec.link, n.nic_up, n.nic_down);
                self.net.set_capacity(up, link);
                self.net.set_capacity(down, link);
                self.dps.note_link_change(node, link.bytes_per_sec());
                true
            }
            FaultEvent::RackLinkDegrade(rack) => {
                // A ToR-uplink brownout: both directions of the shared
                // rack link rescale, throttling exactly the flows that
                // cross the rack boundary (within-rack traffic never
                // touches these resources). Counted with the node-NIC
                // brownouts in `link_degrades`.
                self.n_degrades += 1;
                *self.degraded_racks.entry(rack).or_insert(0) += 1;
                let (up, down, cap) = self.cluster.rack_link(rack);
                let degraded = Bandwidth(cap * self.cfg.fault.degrade_factor.max(1e-6));
                self.net.set_capacity(up, degraded);
                self.net.set_capacity(down, degraded);
                self.dps.note_rack_change(rack, degraded.bytes_per_sec());
                false
            }
            FaultEvent::RackLinkRestore(rack) => {
                let left =
                    self.degraded_racks.get_mut(&rack).expect("restore without rack degrade");
                *left -= 1;
                if *left > 0 {
                    return false;
                }
                self.degraded_racks.remove(&rack);
                let (up, down, cap) = self.cluster.rack_link(rack);
                self.net.set_capacity(up, Bandwidth(cap));
                self.net.set_capacity(down, Bandwidth(cap));
                self.dps.note_rack_change(rack, cap);
                true
            }
        }
    }

    /// A node dies. For the NFS server this is an outage: its channels
    /// stall to ~zero and every DFS flow through them freezes until
    /// recovery. For a worker the full recovery cascade runs: running
    /// tasks are killed and resubmitted, its flows cancelled, doomed
    /// COPs aborted, DPS replicas invalidated, the DFS re-replicates
    /// lost objects, and lost-but-needed intermediates trigger lineage
    /// re-execution.
    fn on_node_crash(&mut self, node: NodeId, now: SimTime) {
        self.n_crashes += 1;
        self.cluster.set_alive(node, false);
        // Availability-aware placement: fold the observed crash into the
        // node's hazard estimate (deterministic EWMA toward 1).
        if self.cfg.resil.hazard_weight > 0.0 {
            self.dps.observe_crash_hazard(node, self.cfg.resil.hazard_alpha);
        }
        if Some(node) == self.cluster.nfs_server() {
            for r in self.cluster.resources_of(node) {
                self.net.set_capacity(r, Bandwidth(1.0));
            }
            self.dps.note_link_change(node, 1.0);
            return;
        }

        // 1. Kill everything running on the node; the work is lost.
        let mut victims: Vec<TaskId> =
            self.running.iter().filter(|(_, r)| r.node == node).map(|(t, _)| *t).collect();
        victims.sort();
        for t in victims {
            self.kill_running(t, now);
        }

        // 2. COPs reading from or writing to the node are doomed —
        //    including those still in their setup window.
        for id in self.dps.cops_touching(node) {
            self.lcs.cancel_cop(id, &mut self.net);
            self.pending_cops.remove(&id);
            if let Some(aborted) = self.dps.abort_cop(id) {
                self.note_cop_aborted(id, aborted.dst);
                self.tracer.emit(now, || TraceEvent::CopAbort { cop: id.0, reason: "node-crash" });
            }
        }

        // 3. Find foreign tasks whose stage-in/out crossed the node
        //    (e.g. a Ceph read from an OSD it hosted) and orphaned
        //    recovery flows; the tasks restart their phase after the
        //    placement heals below.
        let res = self.cluster.resources_of(node);
        let mut affected: Vec<TaskId> = Vec::new();
        for f in self.net.flows_using_any(&res) {
            match self.flow_owner.get(&f).copied() {
                Some(FlowOwner::StageIn(t)) | Some(FlowOwner::StageOut(t)) => {
                    if !affected.contains(&t) {
                        affected.push(t);
                    }
                }
                Some(FlowOwner::Recovery) => {
                    let _ = self.disown_flow(f);
                    self.net.cancel(f);
                }
                Some(FlowOwner::Checkpoint(t)) => {
                    // The checkpoint write lost a leg: the cut fails.
                    // Sibling flows keep draining as traffic; their
                    // completions find no pending entry and are ignored.
                    let _ = self.disown_flow(f);
                    self.net.cancel(f);
                    self.ckpt_pending.remove(&t);
                }
                None => {}
            }
        }
        affected.sort();

        // 4. WOW replicas on the node are gone.
        let lost = self.dps.invalidate_node(node);
        self.node_replica_bytes[node.0] = 0.0;

        // 5. DFS self-healing: Ceph re-replicates the lost objects
        //    (recovery traffic; placement is repaired synchronously).
        for part in self.dfs.fail_node(node, &self.cluster, &mut self.rng) {
            self.recovery_bytes += part.bytes;
            let id = self.net.add_flow(part.bytes, part.resources);
            self.own_flow(id, FlowOwner::Recovery);
        }

        // 6. Restart interrupted phases against the healed placement.
        for t in affected {
            if self.running.contains_key(&t) {
                self.restart_phase_flows(t, now);
            }
        }

        // 7. Re-hedge survivors: a file that lost its replica on the
        //    dead node but survives elsewhere must regain failure-domain
        //    coverage (files with no replica left fall through to
        //    lineage healing below — `ensure_hedged` skips them).
        if self.cfg.resil.hedge_k > 0 {
            for (f, _) in &lost {
                self.ensure_hedged(*f, None);
            }
        }

        // 8. Lineage healing: re-run producers of lost intermediates
        //    that someone still needs (WOW mode only — baselines keep
        //    intermediates in the DFS, which just self-healed).
        self.heal_lost_files(lost);
    }

    /// The node rejoins, empty. The NFS server's channels come back to
    /// spec; a worker returns with full capacity and no data.
    fn on_node_recover(&mut self, node: NodeId) {
        self.cluster.set_alive(node, true);
        if Some(node) == self.cluster.nfs_server() {
            let caps = self.cluster.node(node).spec.channel_caps();
            let res = self.cluster.resources_of(node);
            for (r, cap) in res.into_iter().zip(caps) {
                self.net.set_capacity(r, cap);
            }
            self.dps.note_link_change(node, self.cluster.node(node).spec.link.bytes_per_sec());
        }
    }

    /// Record a flow's owner, maintaining the task → flows reverse
    /// index for stage-in/out flows.
    fn own_flow(&mut self, id: FlowId, owner: FlowOwner) {
        self.flow_owner.insert(id, owner);
        if let FlowOwner::StageIn(t) | FlowOwner::StageOut(t) | FlowOwner::Checkpoint(t) = owner {
            self.task_flows.entry(t).or_default().push(id);
        }
    }

    /// Remove a flow's ownership record (completion, cancellation),
    /// keeping the reverse index in sync. Returns the owner, if any.
    fn disown_flow(&mut self, id: FlowId) -> Option<FlowOwner> {
        let owner = self.flow_owner.remove(&id)?;
        if let FlowOwner::StageIn(t) | FlowOwner::StageOut(t) | FlowOwner::Checkpoint(t) = owner {
            if let Some(flows) = self.task_flows.get_mut(&t) {
                flows.retain(|f| *f != id);
                if flows.is_empty() {
                    self.task_flows.remove(&t);
                }
            }
        }
        Some(owner)
    }

    /// Stage-in/out flows currently owned by `task`, in ascending id
    /// order (flow ids are monotone, so issue order is already sorted).
    fn flows_of_task(&self, task: TaskId) -> Vec<FlowId> {
        let flows = self.task_flows.get(&task).cloned().unwrap_or_default();
        debug_assert!(flows.windows(2).all(|w| w[0] < w[1]), "task flows out of order");
        flows
    }

    /// Kill a task running on a crashed node: cancel its flows, write
    /// off the partial work, resubmit it to the ready queue. The node's
    /// capacity ledger is not released — it resets wholesale when (if)
    /// the node recovers.
    fn kill_running(&mut self, task: TaskId, now: SimTime) {
        // A crashed speculative backup with the race still open is
        // simply discarded — the canonical copy keeps running and the
        // pair dissolves. (A backup that already *won* fell through to
        // the normal path below: it resubmits under the canonical id.)
        if workload::is_spec_task(task)
            && self.spec_pending.remove(&workload::canonical_task(task))
        {
            self.kill_spec_peer(task, now);
            return;
        }
        let r = self.running.remove(&task).expect("running victim");
        let flows = self.flows_of_task(task);
        for f in flows {
            let _ = self.disown_flow(f);
            self.net.cancel(f);
        }
        self.ckpt_pending.remove(&task);
        let wall = (now - r.started).as_secs_f64();
        self.cpu_core_seconds += wall * r.cores as f64;
        self.node_cpu_seconds[r.node.0] += wall * r.cores as f64;
        // Checkpointed progress is not wasted — the rerun resumes from
        // it. `ckpt_wall` is 0 with checkpointing off, so the disabled
        // split is arithmetically identical to `wall * cores`.
        let salvaged = r.ckpt_wall.min(wall);
        self.wasted_core_seconds += (wall - salvaged) * r.cores as f64;
        self.salvaged_core_seconds += salvaged * r.cores as f64;
        self.tasks_rerun += 1;
        self.tracer.emit(now, || TraceEvent::TaskRerun { task: task.0, reason: "crash" });
        self.retries.remove(&task);
        self.tenants[workload::task_tenant(task)].running_cores -= r.cores as u64;
        // `canonical_task` strips the speculation bit (identity on
        // normal ids — a pure bit-and, so the disabled path is
        // unchanged): a crashed winner resubmits as its canonical self.
        self.submit_global(vec![workload::canonical_task(task)]);
    }

    /// A task's current stage-in/out lost flows to a crash elsewhere
    /// (it was reading/writing a replica the dead node held). Cancel
    /// the remnants and re-issue the whole phase against the healed
    /// placement — re-reading already-finished parts is the crash's
    /// collateral damage.
    fn restart_phase_flows(&mut self, task: TaskId, now: SimTime) {
        let (node, phase) = {
            let r = &self.running[&task];
            (r.node, r.phase)
        };
        if phase == Phase::Compute {
            return;
        }
        let flows = self.flows_of_task(task);
        for f in flows {
            let _ = self.disown_flow(f);
            self.net.cancel(f);
        }
        match phase {
            Phase::StageIn => {
                let n_flows = self.issue_stage_in_flows(task, node);
                let r = self.running.get_mut(&task).expect("running");
                r.pending_flows = n_flows;
                if n_flows == 0 {
                    self.begin_compute(task, now);
                }
            }
            Phase::StageOut => {
                // start_stage_out re-issues every output flow and resets
                // the barrier.
                self.start_stage_out(task, now);
            }
            Phase::Compute => unreachable!(),
        }
    }

    /// Re-run producers of lost files that current or future tasks
    /// still need, recursively (a producer's own inputs may be gone
    /// too). Only meaningful in WOW mode — baseline intermediates live
    /// in the self-healing DFS.
    fn heal_lost_files(&mut self, lost: Vec<(FileId, Bytes)>) {
        if !self.scheduler.uses_local_data() {
            return;
        }
        // The stack and the revived list hold namespaced ids; engine
        // queries go through the owning tenant's local ids.
        let mut stack: Vec<FileId> = lost.into_iter().map(|(f, _)| f).collect();
        let mut revived: Vec<TaskId> = Vec::new();
        while let Some(f) = stack.pop() {
            if !self.dps.locations(f).is_empty() {
                continue; // a surviving replica exists elsewhere
            }
            let tn = workload::file_tenant(f);
            let lf = workload::local_file(f);
            let eng = &self.tenants[tn].engine;
            if !eng.file_needed(lf) {
                continue; // nobody will ever read it
            }
            let Some(prod) = eng.file(lf).producer else { continue };
            if !eng.is_done(prod) {
                continue; // already queued, running, or revived
            }
            self.tenants[tn].engine.revive_task(prod);
            self.tenant_unfinished(tn);
            self.tasks_rerun += 1;
            let gid = workload::ns_task(tn, prod);
            let now = self.net.now();
            self.tracer.emit(now, || TraceEvent::TaskRerun { task: gid.0, reason: "lineage" });
            revived.push(gid);
            for &inp in &self.tenants[tn].engine.task(prod).inputs {
                if !self.tenants[tn].engine.file(inp).is_workflow_input() {
                    stack.push(workload::ns_file(tn, inp));
                }
            }
        }
        revived.sort();
        self.submit_global(revived);
    }

    fn finish_metrics(&mut self) -> RunMetrics {
        // Recovery flows can still be in flight when the last task
        // lands: fold their deferred segments so the byte counters
        // below reflect the present, exactly as the eager core's would.
        self.net.sync();
        let unique_generated: Bytes = self
            .tenants
            .iter()
            .flat_map(|t| t.engine.files().iter())
            .filter(|f| !f.is_workflow_input())
            .map(|f| f.size)
            .sum();
        let tasks_total: usize = self.tenants.iter().map(|t| t.engine.n_tasks_materialized()).sum();
        let tasks_no_cop: usize = self
            .tenants
            .iter()
            .enumerate()
            .map(|(tn, t)| {
                (0..t.engine.n_tasks_materialized())
                    .filter(|i| {
                        let id = workload::ns_task(tn, TaskId(*i as u64));
                        !self.tasks_with_cops.contains(&id)
                    })
                    .count()
            })
            .sum();
        let cops_used = self.n_cops_used;

        // Per-node storage: total bytes written to each worker's disk.
        let node_storage_bytes: Vec<f64> = self
            .cluster
            .workers()
            .map(|n| self.net.bytes_through[self.cluster.node(n).disk_write.0])
            .collect();

        // Cross-rack traffic: every transfer leaving a rack crosses
        // exactly one rack uplink (0 on flat — no rack links exist).
        let cross_rack_bytes: f64 =
            self.cluster.rack_uplinks().map(|r| self.net.bytes_through[r.0]).sum();

        let tenant_metrics: Vec<TenantMetrics> = self
            .tenants
            .iter()
            .map(|t| TenantMetrics {
                name: t.name.clone(),
                arrival: t.arrival,
                first_start: t.first_start,
                makespan: t.last_finish.saturating_sub(t.first_start.unwrap_or(SimTime::ZERO)),
                completion: t.last_finish.saturating_sub(t.arrival),
                tasks: t.engine.n_tasks_materialized(),
                rejected: t.rejected,
            })
            .collect();

        // Open-system observables, derived from the same per-tenant
        // accounting the closed-batch report uses. Pure arithmetic over
        // already-collected state, so computing them unconditionally
        // cannot perturb any run.
        let latencies: Vec<f64> = self
            .tenants
            .iter()
            .filter(|t| !t.rejected && t.first_start.is_some())
            .map(|t| t.last_finish.saturating_sub(t.arrival).as_secs_f64())
            .collect();
        let (latency_p50_s, latency_p99_s) = if latencies.is_empty() {
            (0.0, 0.0)
        } else {
            (
                crate::util::stats::percentile(&latencies, 50.0),
                crate::util::stats::percentile(&latencies, 99.0),
            )
        };
        let makespan = self.last_finish.saturating_sub(self.first_start.unwrap_or(SimTime::ZERO));
        let horizon_s = if self.cfg.serve.horizon_s > 0.0 {
            self.cfg.serve.horizon_s
        } else {
            makespan.as_secs_f64()
        };
        let throughput_per_min =
            if horizon_s > 0.0 { latencies.len() as f64 / horizon_s * 60.0 } else { 0.0 };
        let slo_attainment_pct = if self.cfg.serve.slo_s > 0.0 && !latencies.is_empty() {
            let met = latencies.iter().filter(|&&l| l <= self.cfg.serve.slo_s).count();
            100.0 * met as f64 / latencies.len() as f64
        } else {
            0.0
        };
        RunMetrics {
            workflow: self.workload_name.clone(),
            strategy: self.scheduler.name().to_string(),
            dfs: self.dfs.name().to_string(),
            n_nodes: self.cfg.n_nodes,
            link_gbit: self.cfg.link_gbit,
            seed: self.cfg.seed,
            makespan,
            cpu_alloc_hours: self.cpu_core_seconds / 3600.0,
            tasks_total,
            tasks_no_cop,
            cops_created: self.dps.cops_created,
            cops_used,
            cop_bytes: self.dps.bytes_copied,
            unique_generated,
            node_storage_bytes,
            node_cpu_seconds: std::mem::take(&mut self.node_cpu_seconds),
            peak_replica_bytes: self.peak_replica_bytes,
            cross_rack_bytes,
            node_crashes: self.n_crashes,
            link_degrades: self.n_degrades,
            task_failures: self.task_failures,
            tasks_rerun: self.tasks_rerun,
            cops_aborted: self.dps.cops_aborted,
            wasted_compute_hours: self.wasted_core_seconds / 3600.0,
            recovery_bytes: self.recovery_bytes,
            tenants: tenant_metrics,
            tenants_rejected: self.n_rejected,
            tenants_queued: self.n_queued,
            preemptions: self.n_preempted,
            preempted_compute_hours: self.preempted_core_seconds / 3600.0,
            dedup_bytes: self.dedup_bytes,
            latency_p50_s,
            latency_p99_s,
            throughput_per_min,
            slo_attainment_pct,
            hedge_cops: self.n_hedge_cops,
            hedge_bytes: self.hedge_bytes,
            checkpoints: self.n_checkpoints,
            checkpoint_bytes: self.checkpoint_bytes,
            salvaged_compute_hours: self.salvaged_core_seconds / 3600.0,
            speculative_launches: self.n_spec_launches,
            speculative_wins: self.n_spec_wins,
            speculative_wasted_compute_hours: self.spec_wasted_core_seconds / 3600.0,
            estimate_updates: self.oracle.as_ref().map(|o| o.updates()).unwrap_or(0),
            estimate_mae: self.oracle.as_ref().map(|o| o.estimate_mae()).unwrap_or(0.0),
            node_degrades: self.n_unc_degrades,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::patterns;
    use crate::workflow::spec::{ComputeModel, OutputSize, Rule, StageSpec, WorkflowSpec};
    use crate::workflow::task::StageId;

    fn tiny_chain(n_links: usize) -> WorkflowSpec {
        WorkflowSpec {
            name: "tiny-chain".into(),
            stages: vec![
                StageSpec {
                    name: "a".into(),
                    rule: Rule::Source { count: n_links, inputs_per_task: 0 },
                    cores: 1,
                    mem: Bytes::from_gb(1.0),
                    compute: ComputeModel::fixed(5.0),
                    out_count: 1,
                    out_size: OutputSize::FixedGb(0.5),
                },
                StageSpec {
                    name: "b".into(),
                    rule: Rule::PerTask { from: StageId(0) },
                    cores: 1,
                    mem: Bytes::from_gb(1.0),
                    compute: ComputeModel::fixed(2.0),
                    out_count: 1,
                    out_size: OutputSize::RatioOfInput(1.0),
                },
            ],
            input_files_gb: vec![],
        }
    }

    fn cfg(strategy: Strategy, dfs: DfsKind) -> RunConfig {
        RunConfig { n_nodes: 4, strategy, dfs, ..Default::default() }
    }

    #[test]
    fn all_strategies_complete_tiny_chain() {
        for strat in [Strategy::Orig, Strategy::Cws, Strategy::Wow] {
            for dfs in [DfsKind::Ceph, DfsKind::Nfs] {
                let m = run(&tiny_chain(6), &cfg(strat, dfs));
                assert_eq!(m.tasks_total, 12, "{strat:?}/{dfs:?}");
                assert!(m.makespan > SimTime::ZERO);
                assert!(m.cpu_alloc_hours > 0.0);
            }
        }
    }

    #[test]
    fn wow_beats_orig_on_chain_pattern() {
        // The Chain pattern is WOW's optimal case (§VI-A: −86 % on Ceph).
        let spec = patterns::chain();
        let orig = run(&spec, &cfg(Strategy::Orig, DfsKind::Ceph));
        let wow = run(&spec, &cfg(Strategy::Wow, DfsKind::Ceph));
        assert!(
            wow.makespan.as_secs_f64() < 0.6 * orig.makespan.as_secs_f64(),
            "wow {} vs orig {}",
            wow.makespan,
            orig.makespan
        );
    }

    #[test]
    fn wow_chain_needs_no_cops() {
        // Every chain successor can run where its producer ran: ≥98 % of
        // tasks without COPs (Table II: 98.5 %).
        let m = run(&patterns::chain(), &cfg(Strategy::Wow, DfsKind::Ceph));
        assert!(m.pct_tasks_no_cop() > 90.0, "{}", m.pct_tasks_no_cop());
    }

    #[test]
    fn baselines_create_no_cops() {
        let m = run(&tiny_chain(4), &cfg(Strategy::Cws, DfsKind::Ceph));
        assert_eq!(m.cops_created, 0);
        assert_eq!(m.tasks_no_cop, m.tasks_total);
    }

    #[test]
    fn sim_cores_agree_on_tiny_chain() {
        let spec = tiny_chain(5);
        for strat in [Strategy::Orig, Strategy::Wow] {
            let base = run(&spec, &cfg(strat, DfsKind::Ceph));
            for core in [SimCore::Checked, SimCore::Eager, SimCore::Naive] {
                let mut c = cfg(strat, DfsKind::Ceph);
                c.core = core;
                assert_eq!(base, run(&spec, &c), "{strat:?}/{core:?}");
            }
        }
    }

    #[test]
    fn sim_core_parses() {
        assert_eq!("incremental".parse::<SimCore>().unwrap(), SimCore::Incremental);
        assert_eq!("checked".parse::<SimCore>().unwrap(), SimCore::Checked);
        assert_eq!("eager".parse::<SimCore>().unwrap(), SimCore::Eager);
        assert_eq!("naive".parse::<SimCore>().unwrap(), SimCore::Naive);
        assert!("fast".parse::<SimCore>().is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(&tiny_chain(5), &cfg(Strategy::Wow, DfsKind::Ceph));
        let b = run(&tiny_chain(5), &cfg(Strategy::Wow, DfsKind::Ceph));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.cops_created, b.cops_created);
    }

    #[test]
    fn single_node_runs_everything_locally() {
        let mut c = cfg(Strategy::Wow, DfsKind::Ceph);
        c.n_nodes = 1;
        let m = run(&tiny_chain(3), &c);
        assert_eq!(m.cops_created, 0, "one node → nothing to copy");
        assert_eq!(m.tasks_total, 6);
    }

    // ---- fault injection ----

    use crate::fault::FaultConfig;

    /// Crashes early enough to always land inside the run.
    fn crashes(n: usize) -> FaultConfig {
        FaultConfig {
            node_crashes: n,
            crash_window_s: (1.0, 8.0),
            recovery_s: Some(20.0),
            ..Default::default()
        }
    }

    #[test]
    fn node_crashes_complete_under_every_strategy() {
        for strat in [Strategy::Orig, Strategy::Cws, Strategy::Wow] {
            for dfs in [DfsKind::Ceph, DfsKind::Nfs] {
                let mut c = cfg(strat, dfs);
                c.fault = crashes(2);
                let m = run(&tiny_chain(6), &c);
                assert_eq!(m.tasks_total, 12, "{strat:?}/{dfs:?}");
                assert_eq!(m.node_crashes, 2, "{strat:?}/{dfs:?}");
            }
        }
    }

    #[test]
    fn crash_without_recovery_still_completes() {
        for strat in [Strategy::Orig, Strategy::Wow] {
            let mut c = cfg(strat, DfsKind::Ceph);
            c.fault = crashes(2);
            c.fault.recovery_s = None;
            let m = run(&tiny_chain(6), &c);
            assert_eq!(m.tasks_total, 12, "{strat:?}");
        }
    }

    #[test]
    fn task_failures_are_retried_to_completion() {
        let mut c = cfg(Strategy::Wow, DfsKind::Ceph);
        c.fault.task_fail_prob = 0.5;
        c.fault.max_task_retries = 5;
        let m = run(&tiny_chain(6), &c);
        assert_eq!(m.tasks_total, 12, "every task must finish despite failures");
        assert!(m.task_failures > 0, "p=0.5 over 12 tasks: some attempt must fail");
        assert!(m.task_failures <= 12 * 5, "the retry bound caps injections");
        assert!(m.wasted_compute_hours > 0.0);
    }

    #[test]
    fn ceph_crash_produces_recovery_traffic() {
        // Baselines keep all data in Ceph, so an OSD crash must trigger
        // re-replication of everything it held.
        let mut c = cfg(Strategy::Orig, DfsKind::Ceph);
        c.fault = crashes(1);
        // Late enough that the dead OSD already holds written objects.
        c.fault.crash_window_s = (60.0, 120.0);
        let m = run(&patterns::chain(), &c);
        assert_eq!(m.node_crashes, 1);
        assert!(m.recovery_bytes.as_u64() > 0, "OSD held objects → healing traffic");
    }

    #[test]
    fn nfs_outage_stalls_and_recovers() {
        let mut c = cfg(Strategy::Orig, DfsKind::Nfs);
        c.fault.nfs_outage = true;
        c.fault.crash_window_s = (5.0, 10.0);
        c.fault.recovery_s = Some(60.0);
        let m = run(&tiny_chain(6), &c);
        let base = run(&tiny_chain(6), &cfg(Strategy::Orig, DfsKind::Nfs));
        assert_eq!(m.tasks_total, 12);
        assert_eq!(m.node_crashes, 1);
        assert!(
            m.makespan.as_secs_f64() > base.makespan.as_secs_f64() + 30.0,
            "a 60 s outage must stall the DFS-bound run: {} vs {}",
            m.makespan,
            base.makespan
        );
    }

    #[test]
    fn link_brownout_completes_and_is_counted() {
        let mut c = cfg(Strategy::Wow, DfsKind::Ceph);
        c.fault.link_degrades = 2;
        c.fault.crash_window_s = (1.0, 15.0);
        c.fault.degrade_duration_s = 30.0;
        let m = run(&patterns::fork(), &c);
        assert_eq!(m.link_degrades, 2);
        assert_eq!(
            m.tasks_total,
            crate::workflow::engine::WorkflowEngine::dry_run_counts(&patterns::fork(), 0)
                .physical_tasks
        );
    }

    #[test]
    fn disabled_fault_config_reports_zero_fault_metrics() {
        let m = run(&tiny_chain(4), &cfg(Strategy::Wow, DfsKind::Ceph));
        assert_eq!(m.node_crashes, 0);
        assert_eq!(m.link_degrades, 0);
        assert_eq!(m.task_failures, 0);
        assert_eq!(m.tasks_rerun, 0);
        assert_eq!(m.cops_aborted, 0);
        assert_eq!(m.wasted_compute_hours, 0.0);
        assert_eq!(m.recovery_bytes, Bytes::ZERO);
    }

    // ---- serving regime ----

    use crate::workload::TenantSpec;

    /// One stage of 16-core tasks: each occupies a full paper worker.
    fn hog(count: usize) -> WorkflowSpec {
        WorkflowSpec {
            name: "hog".into(),
            stages: vec![StageSpec {
                name: "h".into(),
                rule: Rule::Source { count, inputs_per_task: 0 },
                cores: 16,
                mem: Bytes::from_gb(8.0),
                compute: ComputeModel::fixed(60.0),
                out_count: 1,
                out_size: OutputSize::FixedGb(0.1),
            }],
            input_files_gb: vec![],
        }
    }

    #[test]
    fn preemption_yields_to_the_underserved_tenant() {
        // Tenant 0 saturates both nodes with long tasks; tenant 1
        // arrives later with zero usage, so fair-share ranks it first
        // and its task fits nowhere — preemption must evict for it.
        let workload = WorkloadSpec {
            name: "preempt".into(),
            tenants: vec![
                TenantSpec {
                    name: "hog".into(),
                    workflow: hog(4),
                    arrival: SimTime::ZERO,
                    weight: 1.0,
                },
                TenantSpec {
                    name: "late".into(),
                    workflow: hog(1),
                    arrival: SimTime::from_secs_f64(5.0),
                    weight: 1.0,
                },
            ],
        };
        let mut c = cfg(Strategy::Wow, DfsKind::Ceph);
        c.n_nodes = 2;
        c.tenant_policy = TenantPolicy::FairShare;
        c.serve.preempt = true;
        let m = run_workload(&workload, &c);
        assert!(m.preemptions > 0, "saturated cluster + late tenant must preempt");
        assert!(m.preempted_compute_hours > 0.0);
        assert!(m.tasks_rerun >= m.preemptions, "every eviction reruns its victim");
        assert!(m.tenants.iter().all(|t| !t.rejected && t.first_start.is_some()));
        // Without the preemption pass the same config evicts nothing.
        let mut c2 = c.clone();
        c2.serve.preempt = false;
        assert_eq!(run_workload(&workload, &c2).preemptions, 0);
    }

    #[test]
    fn bounded_queue_sheds_a_flood_and_drains_the_rest() {
        // Six tenants at one-second gaps into one active slot plus a
        // depth-two queue: the first is admitted, two wait, three shed
        // (the first workflow cannot finish within five seconds).
        let tenants: Vec<TenantSpec> = (0..6)
            .map(|i| TenantSpec {
                name: format!("t{i}"),
                workflow: tiny_chain(2),
                arrival: SimTime::from_secs_f64(i as f64),
                weight: 1.0,
            })
            .collect();
        let workload = WorkloadSpec { name: "flood".into(), tenants };
        let mut c = cfg(Strategy::Wow, DfsKind::Ceph);
        c.serve.admission =
            AdmissionPolicy::Queue { active: 1, depth: 2, order: DequeueOrder::Fifo };
        c.serve.slo_s = 30.0;
        let m = run_workload(&workload, &c);
        assert_eq!(m.tenants_rejected, 3);
        assert_eq!(m.tenants_queued, 2);
        let done: Vec<&TenantMetrics> = m.tenants.iter().filter(|t| !t.rejected).collect();
        assert_eq!(done.len(), 3);
        assert!(done.iter().all(|t| t.first_start.is_some()), "queued tenants drain");
        assert!(m.tenants.iter().filter(|t| t.rejected).all(|t| t.first_start.is_none()));
        assert!(m.latency_p50_s > 0.0 && m.latency_p99_s >= m.latency_p50_s);
        assert!(m.throughput_per_min > 0.0);
        assert!(m.slo_attainment_pct > 0.0);
    }

    #[test]
    fn load_shedding_prices_by_estimated_work() {
        // tiny_chain(2) estimates ~14 core-seconds; a 20 core-second
        // budget admits the first arrival and sheds the second.
        let mk = |name: &str, at: f64| TenantSpec {
            name: name.into(),
            workflow: tiny_chain(2),
            arrival: SimTime::from_secs_f64(at),
            weight: 1.0,
        };
        let workload =
            WorkloadSpec { name: "shed".into(), tenants: vec![mk("a", 0.0), mk("b", 1.0)] };
        let mut c = cfg(Strategy::Wow, DfsKind::Ceph);
        c.serve.admission = AdmissionPolicy::LoadShed { max_core_s: 20.0 };
        let m = run_workload(&workload, &c);
        assert_eq!(m.tenants_rejected, 1);
        assert!(m.tenants[0].first_start.is_some() && !m.tenants[0].rejected);
        assert!(m.tenants[1].rejected);
    }

    #[test]
    fn dedup_shares_reference_replicas_across_tenants() {
        let reader = WorkflowSpec {
            name: "reader".into(),
            stages: vec![StageSpec {
                name: "r".into(),
                rule: Rule::Source { count: 1, inputs_per_task: 1 },
                cores: 1,
                mem: Bytes::from_gb(1.0),
                compute: ComputeModel::fixed(5.0),
                out_count: 1,
                out_size: OutputSize::FixedGb(0.1),
            }],
            input_files_gb: vec![1.0],
        };
        // Tenant B arrives after tenant A has staged the shared 1 GB
        // reference input; on one node its read must dedup.
        let workload = WorkloadSpec {
            name: "dedup".into(),
            tenants: vec![
                TenantSpec {
                    name: "a".into(),
                    workflow: reader.clone(),
                    arrival: SimTime::ZERO,
                    weight: 1.0,
                },
                TenantSpec {
                    name: "b".into(),
                    workflow: reader.clone(),
                    arrival: SimTime::from_secs_f64(60.0),
                    weight: 1.0,
                },
            ],
        };
        let mut c = cfg(Strategy::Wow, DfsKind::Ceph);
        c.n_nodes = 1;
        c.serve.dedup = true;
        let m = run_workload(&workload, &c);
        assert!(m.dedup_bytes.0 > 0, "tenant b must share tenant a's replica");
        let mut c2 = c.clone();
        c2.serve.dedup = false;
        assert_eq!(run_workload(&workload, &c2).dedup_bytes, Bytes::ZERO);
    }

    #[test]
    fn wow_crash_forces_lineage_or_cop_recovery() {
        // Chain under WOW keeps every intermediate on exactly one node;
        // crashing nodes mid-run must lose replicas and still finish all
        // tasks via resubmission / lineage healing.
        let mut c = cfg(Strategy::Wow, DfsKind::Ceph);
        c.fault = crashes(2);
        c.fault.crash_window_s = (30.0, 120.0);
        let m = run(&patterns::chain(), &c);
        assert_eq!(m.tasks_total, 200);
        assert_eq!(m.node_crashes, 2);
        assert!(m.tasks_rerun > 0, "crashing mid-chain must discard some work");
    }
}
