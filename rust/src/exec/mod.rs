//! The cluster executor: a discrete-event simulation binding the dynamic
//! workflow engine, a scheduling strategy, the DPS/LCS, a DFS backend,
//! and the flow-level bandwidth substrate.
//!
//! Task lifecycle (mirrors the Nextflow wrapper, §IV-B):
//!
//! ```text
//! ready ──start──▶ stage-in ──▶ compute ──▶ stage-out ──▶ done
//!                  (flows)      (timer)     (flows)
//! ```
//!
//! Baselines stage in/out through the DFS; WOW reads intermediate inputs
//! from the local disk (the node is *prepared*) and writes outputs
//! locally, with COPs moving data between nodes in parallel to execution.
//! A scheduling iteration runs whenever a task finishes, a COP finishes,
//! or new tasks are submitted (§III-B).

use crate::cluster::{Cluster, NodeId, NodeSpec};
use crate::dfs::{Ceph, Dfs, DfsKind, Nfs};
use crate::dps::cost::{CostEval, NativeCost};
use crate::dps::{CopId, Dps};
use crate::lcs::Lcs;
use crate::metrics::RunMetrics;
use crate::net::{FlowId, FlowNet};
use crate::scheduler::wow::WowParams;
use crate::scheduler::{Action, ReadyTask, SchedView, Scheduler, Strategy};
use crate::sim::event::EventQueue;
use crate::util::rng::Rng;
use crate::util::units::{Bytes, SimTime};
use crate::workflow::engine::WorkflowEngine;
use crate::workflow::spec::WorkflowSpec;
use crate::workflow::task::{FileId, TaskId};
use crate::util::fxmap::FastMap;

/// Configuration of one simulated run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub n_nodes: usize,
    pub link_gbit: f64,
    pub dfs: DfsKind,
    pub strategy: Strategy,
    pub seed: u64,
    /// WOW COP limits (§V-C defaults: 1 and 2).
    pub c_node: u32,
    pub c_task: u32,
    /// Per-COP setup latency in seconds (scheduler RPC + FTP session to
    /// the LCS daemon). The paper reuses long-lived LCS daemons exactly
    /// because per-copy service startup "could otherwise double"
    /// short-task runtimes (§IV-D); a sub-second session cost remains.
    pub cop_setup_s: f64,
    /// Replica garbage collection (§III-A): delete all replicas of an
    /// intermediate file once no current or future task can read it.
    /// The paper's evaluation kept every replica ("we did not delete any
    /// replicas during our experiments"), so this defaults to off; the
    /// peak-temporary-storage metric quantifies the §VIII trade-off.
    pub replica_gc: bool,
    /// Per-worker relative compute speeds (empty = homogeneous at 1.0).
    /// Lifts the paper's §VIII homogeneity limitation: task compute time
    /// on node i is divided by `speed_factors[i]`.
    pub speed_factors: Vec<f64>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            n_nodes: 8,
            link_gbit: 1.0,
            dfs: DfsKind::Ceph,
            strategy: Strategy::Wow,
            seed: 0,
            c_node: 1,
            c_task: 2,
            cop_setup_s: 0.5,
            replica_gc: false,
            speed_factors: Vec::new(),
        }
    }
}

/// Run `spec` under `cfg` with the default (native) cost backend.
pub fn run(spec: &WorkflowSpec, cfg: &RunConfig) -> RunMetrics {
    run_with_backend(spec, cfg, Box::new(NativeCost))
}

/// Run with an explicit DPS cost backend (e.g. the XLA artifact).
pub fn run_with_backend(
    spec: &WorkflowSpec,
    cfg: &RunConfig,
    backend: Box<dyn CostEval>,
) -> RunMetrics {
    Executor::new(spec.clone(), cfg.clone(), backend).run()
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    StageIn,
    Compute,
    StageOut,
}

#[derive(Debug)]
struct Running {
    node: NodeId,
    phase: Phase,
    pending_flows: usize,
    started: SimTime,
    cores: u32,
    mem: Bytes,
}

#[derive(Debug)]
enum Event {
    ComputeDone(TaskId),
    /// COP setup latency elapsed: launch its flows.
    CopLaunch(CopId),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum FlowOwner {
    StageIn(TaskId),
    StageOut(TaskId),
}

struct Executor {
    cfg: RunConfig,
    engine: WorkflowEngine,
    scheduler: Box<dyn Scheduler>,
    net: FlowNet,
    cluster: Cluster,
    dfs: Box<dyn Dfs>,
    dps: Dps,
    lcs: Lcs,
    events: EventQueue<Event>,
    rng: Rng,

    ready: Vec<ReadyTask>,
    running: FastMap<TaskId, Running>,
    flow_owner: FastMap<FlowId, FlowOwner>,
    submitted_seq: u64,

    // Metrics accumulation.
    first_start: Option<SimTime>,
    last_finish: SimTime,
    cpu_core_seconds: f64,
    node_cpu_seconds: Vec<f64>,
    cops_per_task: FastMap<TaskId, u32>,
    completed_cops: Vec<(TaskId, NodeId, Vec<FileId>, bool)>, // task, dst, files, used
    /// COPs in their setup-latency window, not yet flowing.
    pending_cops: FastMap<CopId, crate::dps::Cop>,
    tasks_done: usize,
    /// Current / peak bytes of WOW-managed intermediate replicas per
    /// worker (temporary-storage accounting; peak is what §VIII's
    /// fault-tolerance trade-off is about).
    node_replica_bytes: Vec<f64>,
    peak_replica_bytes: f64,
}

impl Executor {
    fn new(spec: WorkflowSpec, cfg: RunConfig, backend: Box<dyn CostEval>) -> Self {
        let mut net = FlowNet::new();
        let needs_server = cfg.dfs == DfsKind::Nfs;
        let mut cluster = Cluster::build(
            &mut net,
            cfg.n_nodes,
            NodeSpec::paper_worker(cfg.link_gbit),
            needs_server.then(|| NodeSpec::paper_nfs_server(cfg.link_gbit)),
        );
        // Heterogeneous compute speeds (§VIII extension).
        for (i, &f) in cfg.speed_factors.iter().enumerate().take(cfg.n_nodes) {
            assert!(f > 0.0, "speed factor must be positive");
            cluster.node_mut(crate::cluster::NodeId(i)).spec.speed = f;
        }
        let dfs: Box<dyn Dfs> = match cfg.dfs {
            DfsKind::Ceph => Box::new(Ceph::new()),
            DfsKind::Nfs => Box::new(Nfs::new(cluster.nfs_server().expect("server"))),
        };
        let params = WowParams {
            c_node: cfg.c_node,
            c_task: cfg.c_task,
            backend,
        };
        let scheduler = cfg.strategy.build(params);
        let engine = WorkflowEngine::new(spec, cfg.seed);
        let n_workers = cluster.n_workers();
        Executor {
            engine,
            scheduler,
            net,
            cluster,
            dfs,
            dps: Dps::new(cfg.seed),
            lcs: Lcs::new(),
            events: EventQueue::new(),
            rng: Rng::new(cfg.seed ^ 0xEC5E_C0DE),
            ready: Vec::new(),
            running: FastMap::default(),
            flow_owner: FastMap::default(),
            submitted_seq: 0,
            first_start: None,
            last_finish: SimTime::ZERO,
            cpu_core_seconds: 0.0,
            node_cpu_seconds: vec![0.0; n_workers],
            cops_per_task: FastMap::default(),
            completed_cops: Vec::new(),
            pending_cops: FastMap::default(),
            tasks_done: 0,
            node_replica_bytes: vec![0.0; n_workers],
            peak_replica_bytes: 0.0,
            cfg,
        }
    }

    fn run(mut self) -> RunMetrics {
        // Register workflow inputs in the DFS (pre-fetched per §V-A).
        for &f in self.engine.input_files().to_vec().iter() {
            let size = self.engine.file(f).size;
            self.dfs.register_input(f, size, &self.cluster, &mut self.rng);
        }
        // Materialize source tasks and run the first iteration.
        let initial = self.engine.start();
        self.submit(initial);
        self.schedule();

        // Main DES loop.
        loop {
            if self.engine.all_done() {
                break;
            }
            let t_flow = self.net.next_completion().unwrap_or(SimTime::FAR_FUTURE);
            let t_event = self.events.peek_time().unwrap_or(SimTime::FAR_FUTURE);
            let t = t_flow.min(t_event);
            assert!(
                t != SimTime::FAR_FUTURE,
                "deadlock: no pending events; ready={} running={} done={}/{}",
                self.ready.len(),
                self.running.len(),
                self.engine.n_tasks_completed(),
                self.engine.n_tasks_materialized()
            );
            self.net.advance_to(t);

            let mut need_schedule = false;

            // Flow completions.
            for flow in self.net.take_completed() {
                if let Some(owner) = self.flow_owner.remove(&flow) {
                    need_schedule |= self.flow_finished(owner, t);
                } else if let Some(cop_id) = self.lcs.flow_done(flow) {
                    self.cop_finished(cop_id);
                    need_schedule = true;
                }
            }
            // Timed events.
            while self.events.peek_time() == Some(t) {
                let (_, ev) = self.events.pop().unwrap();
                match ev {
                    Event::ComputeDone(task) => {
                        self.start_stage_out(task, t);
                    }
                    Event::CopLaunch(id) => {
                        let cop = self.pending_cops.remove(&id).expect("pending COP");
                        self.lcs.start_cop(&cop, &self.cluster, &mut self.net);
                    }
                }
            }
            if need_schedule {
                self.schedule();
            }
        }

        self.finish_metrics()
    }

    /// Queue newly materialized tasks.
    fn submit(&mut self, tasks: Vec<TaskId>) {
        for id in tasks {
            let t = self.engine.task(id);
            let intermediate: Vec<FileId> = t
                .inputs
                .iter()
                .copied()
                .filter(|f| !self.engine.file(*f).is_workflow_input())
                .collect();
            let rt = ReadyTask {
                id,
                cores: t.cores,
                mem: t.mem,
                rank: self.engine.rank_of(id),
                input_bytes: t.input_bytes(self.engine.files()),
                intermediate_inputs: intermediate,
                submitted_seq: self.submitted_seq,
            };
            self.submitted_seq += 1;
            self.ready.push(rt);
        }
    }

    /// One scheduling iteration: ask the strategy, apply its actions.
    fn schedule(&mut self) {
        loop {
            let view = SchedView {
                now: self.net.now(),
                cluster: &self.cluster,
                ready: &self.ready,
            };
            let actions = self.scheduler.iterate(&view, &mut self.dps);
            if actions.is_empty() {
                return;
            }
            let mut progressed = false;
            for action in actions {
                match action {
                    Action::Start { task, node } => {
                        progressed |= self.start_task(task, node);
                    }
                    Action::StartCop { task, dst } => {
                        progressed |= self.start_cop(task, dst);
                    }
                }
            }
            if !progressed {
                return;
            }
            // Starting tasks freed queue slots / changed DPS state; the
            // strategies are written to be idempotent, so loop until
            // quiescent. (Single extra pass in practice.)
            return;
        }
    }

    fn start_task(&mut self, task: TaskId, node: NodeId) -> bool {
        let idx = match self.ready.iter().position(|r| r.id == task) {
            Some(i) => i,
            None => return false, // already started (stale action)
        };
        let rt = self.ready.remove(idx);
        assert!(
            self.cluster.fits(node, rt.cores, rt.mem),
            "scheduler over-subscribed node {node:?} for task {task:?}"
        );
        self.cluster.reserve(node, rt.cores, rt.mem);
        let now = self.net.now();
        self.first_start.get_or_insert(now);

        // Mark used COPs: any completed COP for this task targeting this
        // node whose files intersect the inputs.
        let inputs = &self.engine.task(task).inputs;
        for (ct, dst, files, used) in self.completed_cops.iter_mut() {
            if *used || *dst != node {
                continue;
            }
            let _ = ct;
            if files.iter().any(|f| inputs.contains(f)) {
                *used = true;
            }
        }

        // Stage-in flows.
        let local_mode = self.scheduler.uses_local_data();
        let mut n_flows = 0;
        let input_list: Vec<FileId> = inputs.clone();
        for f in input_list {
            let size = self.engine.file(f).size;
            let is_input = self.engine.file(f).is_workflow_input();
            if local_mode && !is_input {
                // Intermediate input: must be local (node is prepared).
                debug_assert!(
                    self.dps.is_prepared(&[f], node),
                    "task {task:?} started on unprepared node {node:?} (file {f:?})"
                );
                let n = self.cluster.node(node);
                let id = self.net.add_flow(size, vec![n.disk_read]);
                self.flow_owner.insert(id, FlowOwner::StageIn(task));
                n_flows += 1;
            } else {
                for part in self.dfs.read(f, size, node, &self.cluster, &mut self.rng) {
                    let id = self.net.add_flow(part.bytes, part.resources);
                    self.flow_owner.insert(id, FlowOwner::StageIn(task));
                    n_flows += 1;
                }
            }
        }

        self.running.insert(
            task,
            Running {
                node,
                phase: Phase::StageIn,
                pending_flows: n_flows,
                started: now,
                cores: rt.cores,
                mem: rt.mem,
            },
        );
        if n_flows == 0 {
            self.begin_compute(task, now);
        }
        true
    }

    fn begin_compute(&mut self, task: TaskId, now: SimTime) {
        let r = self.running.get_mut(&task).expect("running");
        r.phase = Phase::Compute;
        let node = r.node;
        // Heterogeneous speeds: slower nodes stretch compute (§VIII).
        let speed = self.cluster.node(node).spec.speed;
        let base = self.engine.task(task).compute;
        let dur = if speed == 1.0 {
            base
        } else {
            SimTime::from_secs_f64(base.as_secs_f64() / speed)
        };
        self.events.push(now + dur, Event::ComputeDone(task));
    }

    fn start_stage_out(&mut self, task: TaskId, now: SimTime) {
        let local_mode = self.scheduler.uses_local_data();
        let node = self.running[&task].node;
        let outputs = self.engine.task(task).outputs.clone();
        let mut n_flows = 0;
        for (f, size) in outputs {
            if local_mode {
                let n = self.cluster.node(node);
                let id = self.net.add_flow(size, vec![n.disk_write]);
                self.flow_owner.insert(id, FlowOwner::StageOut(task));
                n_flows += 1;
            } else {
                for part in self.dfs.write(f, size, node, &self.cluster, &mut self.rng) {
                    let id = self.net.add_flow(part.bytes, part.resources);
                    self.flow_owner.insert(id, FlowOwner::StageOut(task));
                    n_flows += 1;
                }
            }
        }
        let r = self.running.get_mut(&task).expect("running");
        r.phase = Phase::StageOut;
        r.pending_flows = n_flows;
        if n_flows == 0 {
            self.complete_task(task, now);
        }
    }

    /// Returns true if the completion should trigger a scheduling
    /// iteration.
    fn flow_finished(&mut self, owner: FlowOwner, now: SimTime) -> bool {
        match owner {
            FlowOwner::StageIn(task) => {
                let r = self.running.get_mut(&task).expect("running task");
                debug_assert_eq!(r.phase, Phase::StageIn);
                r.pending_flows -= 1;
                if r.pending_flows == 0 {
                    self.begin_compute(task, now);
                }
                false
            }
            FlowOwner::StageOut(task) => {
                let r = self.running.get_mut(&task).expect("running task");
                debug_assert_eq!(r.phase, Phase::StageOut);
                r.pending_flows -= 1;
                if r.pending_flows == 0 {
                    self.complete_task(task, now);
                    return true;
                }
                false
            }
        }
    }

    fn complete_task(&mut self, task: TaskId, now: SimTime) {
        let r = self.running.remove(&task).expect("running");
        self.cluster.release(r.node, r.cores, r.mem);
        let wall = (now - r.started).as_secs_f64();
        self.cpu_core_seconds += wall * r.cores as f64;
        self.node_cpu_seconds[r.node.0] += wall * r.cores as f64;
        self.last_finish = now;
        self.tasks_done += 1;

        // Outputs become visible; in WOW mode they are DPS-managed local
        // files.
        if self.scheduler.uses_local_data() {
            for (f, size) in self.engine.task(task).outputs.clone() {
                self.dps.register_output(f, size, r.node);
                self.node_replica_bytes[r.node.0] += size.as_f64();
            }
            self.update_peak();
        }
        let newly_ready = self.engine.complete_task(task);
        // Replica GC (§III-A): free intermediate files no task can read
        // any more.
        if self.cfg.replica_gc && self.scheduler.uses_local_data() {
            for f in self.engine.take_dead_files() {
                let size = self.engine.file(f).size.as_f64();
                for node in self.dps.release_file(f) {
                    self.node_replica_bytes[node.0] -= size;
                }
            }
        } else {
            self.engine.take_dead_files();
        }
        self.submit(newly_ready);
    }

    fn update_peak(&mut self) {
        let total: f64 = self.node_replica_bytes.iter().sum();
        if total > self.peak_replica_bytes {
            self.peak_replica_bytes = total;
        }
    }

    fn start_cop(&mut self, task: TaskId, dst: NodeId) -> bool {
        // The scheduler checked feasibility; re-plan for fresh sources.
        let inputs = match self.ready.iter().find(|r| r.id == task) {
            Some(r) => r.intermediate_inputs.clone(),
            None => return false, // task started in the same batch
        };
        let plan = match self.dps.plan(&inputs, dst) {
            Some(p) => p,
            None => return false,
        };
        let cop = self.dps.start_cop(task, dst, plan);
        *self.cops_per_task.entry(task).or_insert(0) += 1;
        // Setup latency before bytes move; the COP occupies its c_node /
        // c_task slots for the whole window (reserved at creation).
        let launch_at = self.net.now() + SimTime::from_secs_f64(self.cfg.cop_setup_s);
        self.pending_cops.insert(cop.id, cop.clone());
        self.events.push(launch_at, Event::CopLaunch(cop.id));
        true
    }

    fn cop_finished(&mut self, id: CopId) {
        let cop = self.dps.complete_cop(id);
        for (_, _, size) in &cop.parts {
            self.node_replica_bytes[cop.dst.0] += size.as_f64();
        }
        self.update_peak();
        let files = cop.parts.iter().map(|(f, _, _)| *f).collect();
        self.completed_cops.push((cop.task, cop.dst, files, false));
    }

    fn finish_metrics(self) -> RunMetrics {
        let unique_generated: Bytes = self
            .engine
            .files()
            .iter()
            .filter(|f| !f.is_workflow_input())
            .map(|f| f.size)
            .sum();
        let tasks_total = self.engine.n_tasks_materialized();
        let tasks_no_cop = (0..tasks_total)
            .filter(|i| !self.cops_per_task.contains_key(&TaskId(*i as u64)))
            .count();
        let cops_used = self.completed_cops.iter().filter(|(_, _, _, used)| *used).count() as u64;

        // Per-node storage: total bytes written to each worker's disk.
        let node_storage_bytes: Vec<f64> = self
            .cluster
            .workers()
            .map(|n| self.net.bytes_through[self.cluster.node(n).disk_write.0])
            .collect();

        let makespan = self
            .last_finish
            .saturating_sub(self.first_start.unwrap_or(SimTime::ZERO));
        RunMetrics {
            workflow: self.engine.name().to_string(),
            strategy: self.scheduler.name().to_string(),
            dfs: self.dfs.name().to_string(),
            n_nodes: self.cfg.n_nodes,
            link_gbit: self.cfg.link_gbit,
            seed: self.cfg.seed,
            makespan,
            cpu_alloc_hours: self.cpu_core_seconds / 3600.0,
            tasks_total,
            tasks_no_cop,
            cops_created: self.dps.cops_created,
            cops_used,
            cop_bytes: self.dps.bytes_copied,
            unique_generated,
            node_storage_bytes,
            node_cpu_seconds: self.node_cpu_seconds.clone(),
            peak_replica_bytes: self.peak_replica_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::patterns;
    use crate::workflow::spec::{ComputeModel, OutputSize, Rule, StageSpec, WorkflowSpec};
    use crate::workflow::task::StageId;

    fn tiny_chain(n_links: usize) -> WorkflowSpec {
        WorkflowSpec {
            name: "tiny-chain".into(),
            stages: vec![
                StageSpec {
                    name: "a".into(),
                    rule: Rule::Source { count: n_links, inputs_per_task: 0 },
                    cores: 1,
                    mem: Bytes::from_gb(1.0),
                    compute: ComputeModel::fixed(5.0),
                    out_count: 1,
                    out_size: OutputSize::FixedGb(0.5),
                },
                StageSpec {
                    name: "b".into(),
                    rule: Rule::PerTask { from: StageId(0) },
                    cores: 1,
                    mem: Bytes::from_gb(1.0),
                    compute: ComputeModel::fixed(2.0),
                    out_count: 1,
                    out_size: OutputSize::RatioOfInput(1.0),
                },
            ],
            input_files_gb: vec![],
        }
    }

    fn cfg(strategy: Strategy, dfs: DfsKind) -> RunConfig {
        RunConfig { n_nodes: 4, strategy, dfs, ..Default::default() }
    }

    #[test]
    fn all_strategies_complete_tiny_chain() {
        for strat in [Strategy::Orig, Strategy::Cws, Strategy::Wow] {
            for dfs in [DfsKind::Ceph, DfsKind::Nfs] {
                let m = run(&tiny_chain(6), &cfg(strat, dfs));
                assert_eq!(m.tasks_total, 12, "{strat:?}/{dfs:?}");
                assert!(m.makespan > SimTime::ZERO);
                assert!(m.cpu_alloc_hours > 0.0);
            }
        }
    }

    #[test]
    fn wow_beats_orig_on_chain_pattern() {
        // The Chain pattern is WOW's optimal case (§VI-A: −86 % on Ceph).
        let spec = patterns::chain();
        let orig = run(&spec, &cfg(Strategy::Orig, DfsKind::Ceph));
        let wow = run(&spec, &cfg(Strategy::Wow, DfsKind::Ceph));
        assert!(
            wow.makespan.as_secs_f64() < 0.6 * orig.makespan.as_secs_f64(),
            "wow {} vs orig {}",
            wow.makespan,
            orig.makespan
        );
    }

    #[test]
    fn wow_chain_needs_no_cops() {
        // Every chain successor can run where its producer ran: ≥98 % of
        // tasks without COPs (Table II: 98.5 %).
        let m = run(&patterns::chain(), &cfg(Strategy::Wow, DfsKind::Ceph));
        assert!(m.pct_tasks_no_cop() > 90.0, "{}", m.pct_tasks_no_cop());
    }

    #[test]
    fn baselines_create_no_cops() {
        let m = run(&tiny_chain(4), &cfg(Strategy::Cws, DfsKind::Ceph));
        assert_eq!(m.cops_created, 0);
        assert_eq!(m.tasks_no_cop, m.tasks_total);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(&tiny_chain(5), &cfg(Strategy::Wow, DfsKind::Ceph));
        let b = run(&tiny_chain(5), &cfg(Strategy::Wow, DfsKind::Ceph));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.cops_created, b.cops_created);
    }

    #[test]
    fn single_node_runs_everything_locally() {
        let mut c = cfg(Strategy::Wow, DfsKind::Ceph);
        c.n_nodes = 1;
        let m = run(&tiny_chain(3), &c);
        assert_eq!(m.cops_created, 0, "one node → nothing to copy");
        assert_eq!(m.tasks_total, 6);
    }
}
