//! The Local Copy Service (§III-A, §IV-D).
//!
//! In the paper, an LCS daemon on every node exposes local storage via
//! FTP and executes the COPs the DPS hands it, moving intermediate data
//! directly node-to-node and bypassing the DFS. In the simulator the LCS
//! maps each COP part onto a network flow: source disk read → source NIC
//! up → destination NIC down → destination disk write. The COP-level
//! barrier (a COP is atomic, §IV-C) is tracked here.

use crate::cluster::Cluster;
use crate::dps::{Cop, CopId};
use crate::net::{FlowId, FlowNet};
use crate::util::fxmap::FastMap;

/// Tracks in-flight COP flows and their COP-level barrier.
#[derive(Debug, Default)]
pub struct Lcs {
    /// flow → owning COP.
    flow_cop: FastMap<FlowId, CopId>,
    /// COP → its unfinished flows, in launch (= ascending id) order.
    /// The reverse index makes crash-time cancellation O(parts) instead
    /// of a scan over every in-flight flow; the COP barrier fires when
    /// the vector drains.
    cop_flows: FastMap<CopId, Vec<FlowId>>,
}

impl Lcs {
    pub fn new() -> Self {
        Self::default()
    }

    /// Launch all flows of `cop`. One flow per file part, direct
    /// node-to-node (never touching the DFS).
    pub fn start_cop(&mut self, cop: &Cop, cluster: &Cluster, net: &mut FlowNet) {
        assert!(!cop.parts.is_empty(), "empty COP");
        let mut flows = Vec::with_capacity(cop.parts.len());
        for (_, src, size) in &cop.parts {
            debug_assert_ne!(*src, cop.dst, "COP to the node that already holds the file");
            // Source disk → link chain (NICs plus any rack/zone
            // boundary links) → destination disk.
            let fid = net.add_flow(*size, cluster.transfer_path(*src, cop.dst));
            self.flow_cop.insert(fid, cop.id);
            flows.push(fid);
        }
        self.cop_flows.insert(cop.id, flows);
    }

    /// A flow completed. Returns `Some(cop)` when this was the last
    /// pending flow of its COP (the COP barrier).
    pub fn flow_done(&mut self, flow: FlowId) -> Option<CopId> {
        let cop = self.flow_cop.remove(&flow)?;
        let left = self.cop_flows.get_mut(&cop).expect("cop flows");
        left.retain(|f| *f != flow);
        if left.is_empty() {
            self.cop_flows.remove(&cop);
            Some(cop)
        } else {
            None
        }
    }

    /// Cancel every in-flight flow of `cop` (a node crash doomed it) and
    /// drop its barrier. Returns the number of flows cancelled (0 if the
    /// COP had none in flight, e.g. still in its setup window).
    pub fn cancel_cop(&mut self, cop: CopId, net: &mut FlowNet) -> usize {
        let flows = self.cop_flows.remove(&cop).unwrap_or_default();
        for f in &flows {
            self.flow_cop.remove(f);
            net.cancel(*f);
        }
        flows.len()
    }

    /// Is this flow part of a COP?
    pub fn owns_flow(&self, flow: FlowId) -> bool {
        self.flow_cop.contains_key(&flow)
    }

    pub fn active_cops(&self) -> usize {
        self.cop_flows.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeId, NodeSpec};
    use crate::dps::Cop;
    use crate::util::units::Bytes;
    use crate::workflow::task::{FileId, TaskId};

    fn setup() -> (FlowNet, Cluster) {
        let mut net = FlowNet::new();
        let c = Cluster::build(&mut net, 3, NodeSpec::paper_worker(1.0), None);
        (net, c)
    }

    #[test]
    fn cop_barrier_waits_for_all_flows() {
        let (mut net, c) = setup();
        let mut lcs = Lcs::new();
        let cop = Cop {
            id: CopId(0),
            task: TaskId(0),
            dst: NodeId(0),
            parts: vec![
                (FileId(1), NodeId(1), Bytes::from_gb(1.0)),
                (FileId(2), NodeId(2), Bytes::from_gb(2.0)),
            ],
        };
        lcs.start_cop(&cop, &c, &mut net);
        assert_eq!(lcs.active_cops(), 1);
        // Run until both flows complete.
        let mut done_cop = None;
        while net.active_flows() > 0 {
            let t = net.next_completion().unwrap();
            net.advance_to(t);
            for f in net.take_completed() {
                assert!(lcs.owns_flow(f) || done_cop.is_some());
                if let Some(cid) = lcs.flow_done(f) {
                    assert!(done_cop.is_none(), "barrier fired twice");
                    done_cop = Some(cid);
                }
            }
        }
        assert_eq!(done_cop, Some(CopId(0)));
        assert_eq!(lcs.active_cops(), 0);
    }

    #[test]
    fn cancel_cop_removes_its_flows_and_barrier() {
        let (mut net, c) = setup();
        let mut lcs = Lcs::new();
        let cop = Cop {
            id: CopId(3),
            task: TaskId(1),
            dst: NodeId(0),
            parts: vec![
                (FileId(1), NodeId(1), Bytes::from_gb(1.0)),
                (FileId(2), NodeId(2), Bytes::from_gb(1.0)),
            ],
        };
        lcs.start_cop(&cop, &c, &mut net);
        assert_eq!(net.active_flows(), 2);
        assert_eq!(lcs.cancel_cop(CopId(3), &mut net), 2);
        assert_eq!(net.active_flows(), 0);
        assert_eq!(lcs.active_cops(), 0);
        assert_eq!(lcs.cancel_cop(CopId(3), &mut net), 0, "idempotent");
    }

    #[test]
    fn unrelated_flows_ignored() {
        let (mut net, c) = setup();
        let mut lcs = Lcs::new();
        let n0 = c.node(NodeId(0));
        let f = net.add_flow(Bytes(10), vec![n0.disk_read]);
        assert!(!lcs.owns_flow(f));
        assert!(lcs.flow_done(f).is_none());
    }
}
