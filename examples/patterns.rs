//! Fig 3 patterns under all strategies: regenerates the pattern block
//! of Table II on one seed.
//!
//! ```bash
//! cargo run --release --example patterns
//! ```

use wow::dfs::DfsKind;
use wow::exec::{run, RunConfig};
use wow::report::Table;
use wow::scheduler::Strategy;
use wow::util::stats::rel_change_pct;
use wow::workflow::patterns;

fn main() {
    for dfs in [DfsKind::Ceph, DfsKind::Nfs] {
        let mut t = Table::new(
            &format!("Workflow patterns (Fig 3) on {} — 8 nodes, 1 Gbit", dfs.label()),
            &["Pattern", "Orig [min]", "CWS", "WOW", "WOW COPs", "no-COP"],
        );
        for spec in patterns::all_patterns() {
            let m = |s: Strategy| {
                run(&spec, &RunConfig { dfs, strategy: s, ..Default::default() })
            };
            let orig = m(Strategy::Orig);
            let cws = m(Strategy::Cws);
            let wowm = m(Strategy::Wow);
            t.row(vec![
                spec.name.clone(),
                format!("{:.1}", orig.makespan_min()),
                format!("{:+.1}%", rel_change_pct(orig.makespan_min(), cws.makespan_min())),
                format!("{:+.1}%", rel_change_pct(orig.makespan_min(), wowm.makespan_min())),
                wowm.cops_created.to_string(),
                format!("{:.1}%", wowm.pct_tasks_no_cop()),
            ]);
        }
        println!("{}", t.render());
    }
}
