//! Fig 4 (data overhead) on patterns + synthetic workflows: replica
//! bytes copied by WOW COPs relative to unique generated data.
//!
//! ```bash
//! cargo run --release --example data_overhead
//! ```

use wow::dfs::DfsKind;
use wow::exec::{run, RunConfig};
use wow::report::Table;
use wow::scheduler::Strategy;

fn main() {
    let mut specs = wow::workflow::synthetic::all_synthetic();
    specs.extend(wow::workflow::patterns::all_patterns());
    let mut t = Table::new(
        "WOW data overhead (Ceph ref = 100%, NFS ref = 0%)",
        &["Workflow", "WOW on Ceph", "WOW on NFS", "COPs", "COPs used"],
    );
    for spec in specs {
        let ceph = run(
            &spec,
            &RunConfig { dfs: DfsKind::Ceph, strategy: Strategy::Wow, ..Default::default() },
        );
        let nfs = run(
            &spec,
            &RunConfig { dfs: DfsKind::Nfs, strategy: Strategy::Wow, ..Default::default() },
        );
        t.row(vec![
            spec.name.clone(),
            format!("{:.1}%", ceph.data_overhead_pct()),
            format!("{:.1}%", nfs.data_overhead_pct()),
            ceph.cops_created.to_string(),
            format!("{:.1}%", ceph.pct_cops_used()),
        ]);
    }
    println!("{}", t.render());
}
