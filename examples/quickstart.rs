//! Quickstart: simulate the Chain pattern (WOW's showcase workflow)
//! under all three scheduling strategies on a Ceph-backed 8-node
//! cluster and compare makespans.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use wow::dfs::DfsKind;
use wow::exec::{run, RunConfig};
use wow::report::Table;
use wow::scheduler::Strategy;
use wow::util::stats::rel_change_pct;
use wow::workflow::patterns;

fn main() {
    let spec = patterns::chain();
    println!("workflow: {} ({} abstract stages)\n", spec.name, spec.stages.len());

    let mut results = Vec::new();
    for strategy in [Strategy::Orig, Strategy::Cws, Strategy::Wow] {
        let cfg = RunConfig {
            n_nodes: 8,
            link_gbit: 1.0,
            dfs: DfsKind::Ceph,
            strategy,
            ..Default::default()
        };
        results.push(run(&spec, &cfg));
    }

    let orig_makespan = results[0].makespan_min();
    let mut t = Table::new(
        "Chain pattern — 8 nodes, 1 Gbit, Ceph",
        &["Strategy", "Makespan [min]", "vs Orig", "CPU [h]", "COPs", "Overhead"],
    );
    for m in &results {
        t.row(vec![
            m.strategy.to_uppercase(),
            format!("{:.1}", m.makespan_min()),
            format!("{:+.1}%", rel_change_pct(orig_makespan, m.makespan_min())),
            format!("{:.1}", m.cpu_alloc_hours),
            m.cops_created.to_string(),
            format!("{:.1}%", m.data_overhead_pct()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "WOW keeps each chain's intermediate file on the node that produced\n\
         it, so successor tasks start on *prepared* nodes and no bytes cross\n\
         the network (paper Table II: -86.4% makespan on Ceph)."
    );
}
