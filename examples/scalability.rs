//! Fig 5 (scalability) on the Chain pattern: makespan + efficiency for
//! 1..8 nodes, WOW vs CWS.
//!
//! ```bash
//! cargo run --release --example scalability
//! ```

use wow::dfs::DfsKind;
use wow::exec::{run, RunConfig};
use wow::report::Table;
use wow::scheduler::Strategy;
use wow::workflow::patterns;

fn main() {
    let spec = patterns::chain();
    for dfs in [DfsKind::Ceph, DfsKind::Nfs] {
        let mut t = Table::new(
            &format!(
                "Chain scalability on {} (efficiency = makespan(1)/(makespan(n)*n))",
                dfs.label()
            ),
            &["Nodes", "CWS [min]", "CWS eff", "WOW [min]", "WOW eff"],
        );
        let mut base = [f64::NAN; 2];
        for n in [1usize, 2, 4, 6, 8] {
            let mut row = vec![n.to_string()];
            for (i, strat) in [Strategy::Cws, Strategy::Wow].into_iter().enumerate() {
                let cfg = RunConfig { n_nodes: n, dfs, strategy: strat, ..Default::default() };
                let m = run(&spec, &cfg).makespan_min();
                if n == 1 {
                    base[i] = m;
                }
                row.push(format!("{m:.1}"));
                row.push(format!("{:.0}%", base[i] / (m * n as f64) * 100.0));
            }
            // reorder: nodes, cws, cws eff, wow, wow eff
            t.row(row);
        }
        println!("{}", t.render());
    }
}
