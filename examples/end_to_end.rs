//! End-to-end driver — the full-system validation run.
//!
//! Exercises every layer on a real full-scale workload: the Chip-Seq
//! trace model (3,537 physical tasks, 141 GB input, 787 GB generated —
//! Table I) executed on the simulated 8-node / 1 Gbit cluster under all
//! three strategies and both DFS backends, with the DPS served by the
//! **AOT XLA artifact** (Pallas kernel -> JAX graph -> HLO -> PJRT)
//! when available. Prints the paper-vs-measured headline metrics that
//! EXPERIMENTS.md records.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use wow::dfs::DfsKind;
use wow::exec::{run_with_backend, RunConfig};
use wow::exp::make_backend;
use wow::report::Table;
use wow::scheduler::Strategy;
use wow::util::stats::rel_change_pct;
use wow::workflow::realworld;

fn main() {
    let spec = realworld::chipseq();
    let use_xla = {
        #[cfg(feature = "xla-runtime")]
        {
            wow::runtime::XlaCostModel::available()
        }
        #[cfg(not(feature = "xla-runtime"))]
        {
            false
        }
    };
    eprintln!(
        "end-to-end: {} | {} tasks | DPS backend: {}",
        spec.name,
        wow::workflow::engine::WorkflowEngine::dry_run_counts(&spec, 0).physical_tasks,
        if use_xla { "XLA (AOT artifact)" } else { "native (run `make artifacts` for XLA)" },
    );

    // Paper Table II reference deltas for Chip-Seq (WOW vs Orig).
    let paper = [(DfsKind::Ceph, -15.4), (DfsKind::Nfs, -44.8)];

    let mut t = Table::new(
        "End-to-end: Chip-Seq, 8 nodes, 1 Gbit",
        &[
            "DFS",
            "Strategy",
            "Makespan [min]",
            "vs Orig",
            "CPU [h]",
            "no-COP",
            "COPs used",
            "wall [s]",
        ],
    );
    let mut summary = Vec::new();
    for (dfs, paper_delta) in paper {
        let mut orig_min = 0.0;
        for strategy in [Strategy::Orig, Strategy::Cws, Strategy::Wow] {
            let cfg = RunConfig { n_nodes: 8, link_gbit: 1.0, dfs, strategy, ..Default::default() };
            let t0 = std::time::Instant::now();
            let m = run_with_backend(&spec, &cfg, make_backend(use_xla));
            let wall = t0.elapsed().as_secs_f64();
            if strategy == Strategy::Orig {
                orig_min = m.makespan_min();
            }
            let delta = rel_change_pct(orig_min, m.makespan_min());
            if strategy == Strategy::Wow {
                summary.push((dfs, delta, paper_delta));
            }
            t.row(vec![
                dfs.label().into(),
                strategy.label().into(),
                format!("{:.1}", m.makespan_min()),
                format!("{delta:+.1}%"),
                format!("{:.1}", m.cpu_alloc_hours),
                format!("{:.1}%", m.pct_tasks_no_cop()),
                format!("{:.1}%", m.pct_cops_used()),
                format!("{wall:.2}"),
            ]);
        }
    }
    println!("{}", t.render());
    for (dfs, ours, paper) in summary {
        println!(
            "headline ({}): WOW makespan {:+.1}% vs Orig (paper Table II: {:+.1}%)",
            dfs.label(),
            ours,
            paper
        );
    }
}
