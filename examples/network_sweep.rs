//! Table III (network dependence) on the patterns: makespan change when
//! the links go from 1 Gbit to 2 Gbit. WOW should barely care.
//!
//! ```bash
//! cargo run --release --example network_sweep
//! ```

use wow::dfs::DfsKind;
use wow::exec::{run, RunConfig};
use wow::report::Table;
use wow::scheduler::Strategy;
use wow::util::stats::rel_change_pct;
use wow::workflow::patterns;

fn main() {
    let mut t = Table::new(
        "Makespan change 1 Gbit -> 2 Gbit (Ceph)",
        &["Pattern", "Orig", "CWS", "WOW"],
    );
    for spec in patterns::all_patterns() {
        let mut row = vec![spec.name.clone()];
        for strat in [Strategy::Orig, Strategy::Cws, Strategy::Wow] {
            let cfg1 = RunConfig {
                dfs: DfsKind::Ceph,
                strategy: strat,
                link_gbit: 1.0,
                ..Default::default()
            };
            let m1 = run(&spec, &cfg1);
            let cfg2 = RunConfig {
                dfs: DfsKind::Ceph,
                strategy: strat,
                link_gbit: 2.0,
                ..Default::default()
            };
            let m2 = run(&spec, &cfg2);
            row.push(format!(
                "{:+.1}%",
                rel_change_pct(m1.makespan_min(), m2.makespan_min())
            ));
        }
        t.row(row);
    }
    println!("{}", t.render());
    println!("Lower |change| = less network-bound (paper Table III: WOW smallest).");
}
